//! Crash-consistency property tests for the checkpoint codec: random
//! snapshots survive an encode/decode roundtrip bitwise; random bit
//! flips and truncations are rejected with a typed error (never a panic,
//! never a silent partial load); and `latest_valid` always returns the
//! newest file that still validates.

use flowmoe::ft::ckpt::{decode, encode, save_atomic};
use flowmoe::ft::{latest_valid, Checkpoint};
use flowmoe::prop_assert;
use flowmoe::testutil::prop;
use flowmoe::util::Rng;

fn random_ckpt(rng: &mut Rng) -> Checkpoint {
    let n_workers = rng.range(1, 4);
    let n_tensors = rng.range(1, 5);
    let mut params = Vec::new();
    let mut moms = Vec::new();
    for _ in 0..n_tensors {
        let len = rng.below(32);
        params.push((0..len).map(|_| rng.f32() - 0.5).collect());
        moms.push((0..len).map(|_| rng.f32() - 0.5).collect());
    }
    Checkpoint {
        cfg: ["tiny", "e2e", ""][rng.below(3)].to_string(),
        step: rng.next_u64() % 10_000,
        corpus_rng: (0..n_workers)
            .map(|_| [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()])
            .collect(),
        params,
        moms,
    }
}

#[test]
fn roundtrip_is_bitwise() {
    prop::check(40, |rng| {
        let ck = random_ckpt(rng);
        let back = decode(&encode(&ck)).map_err(|e| format!("decode: {e}"))?;
        prop_assert!(back == ck, "roundtrip changed the checkpoint");
        Ok(())
    });
}

#[test]
fn random_bit_flip_is_typed_error() {
    prop::check(60, |rng| {
        let ck = random_ckpt(rng);
        let mut bytes = encode(&ck);
        let pos = rng.below(bytes.len());
        let bit = 1u8 << rng.below(8);
        bytes[pos] ^= bit;
        // Any single-bit flip must surface as Err: header flips hit the
        // magic/version/CRC checks, payload flips hit the CRC (CRC-32
        // detects all single-bit errors). Must not panic.
        prop_assert!(
            decode(&bytes).is_err(),
            "bit flip at byte {pos} (mask {bit:#04x}) decoded cleanly"
        );
        Ok(())
    });
}

#[test]
fn random_truncation_is_typed_error() {
    prop::check(60, |rng| {
        let ck = random_ckpt(rng);
        let bytes = encode(&ck);
        let keep = rng.below(bytes.len()); // strictly shorter prefix
        prop_assert!(
            decode(&bytes[..keep]).is_err(),
            "truncation to {keep}/{} bytes decoded cleanly",
            bytes.len()
        );
        Ok(())
    });
}

#[test]
fn garbage_is_typed_error_without_huge_alloc() {
    // Adversarial payloads with absurd length prefixes must error out
    // before any giant allocation is attempted.
    prop::check(40, |rng| {
        let n = rng.range(16, 64);
        let mut bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        bytes[0..4].copy_from_slice(b"FMCK");
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        // absurd cfg length prefix, far beyond the payload
        bytes[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        // make the CRC match so decode reaches the payload parser
        let crc = flowmoe::ft::ckpt::crc32(&bytes[12..]);
        bytes[8..12].copy_from_slice(&crc.to_le_bytes());
        prop_assert!(decode(&bytes).is_err(), "random payload decoded cleanly");
        Ok(())
    });
}

#[test]
fn newest_valid_wins_under_random_corruption() {
    prop::check(20, |rng| {
        let dir = std::env::temp_dir().join(format!(
            "flowmoe_ft_prop_{}_{}",
            std::process::id(),
            rng.next_u64()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        // Save 4 checkpoints at increasing steps, then corrupt a random
        // suffix of the newest ones; latest_valid must return the newest
        // untouched file.
        let mut paths = Vec::new();
        let mut cks = Vec::new();
        for step in [3u64, 7, 11, 19] {
            let mut ck = random_ckpt(rng);
            ck.step = step;
            paths.push(save_atomic(&dir, &ck).map_err(|e| format!("save: {e}"))?);
            cks.push(ck);
        }
        let corrupt_from = rng.range(1, 4); // leave at least the oldest intact
        for path in &paths[corrupt_from..] {
            let mut bytes = std::fs::read(path).map_err(|e| format!("read: {e}"))?;
            let pos = rng.below(bytes.len());
            bytes[pos] ^= 1 << rng.below(8);
            std::fs::write(path, &bytes).map_err(|e| format!("write: {e}"))?;
        }
        let got = latest_valid(&dir).map_err(|e| format!("latest_valid: {e}"))?;
        let _ = std::fs::remove_dir_all(&dir);
        let (path, ck) = got.ok_or("no valid checkpoint found")?;
        prop_assert!(
            path == paths[corrupt_from - 1],
            "expected {:?}, got {path:?}",
            paths[corrupt_from - 1]
        );
        prop_assert!(ck == cks[corrupt_from - 1], "payload mismatch for newest valid");
        Ok(())
    });
}
