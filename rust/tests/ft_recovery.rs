//! Fault-tolerance integration: the bitwise resume contract
//! (train 2N == train N + checkpoint + restore + train N), the full
//! kill -> typed detection -> re-shard to P-1 -> restore -> continue
//! recovery loop, and the cluster A2A hang-class regression (a killed
//! worker surfaces as a typed error within the detection window, never
//! a hang).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use flowmoe::cluster::{ep_geometry, run_ep_cluster_faulty};
use flowmoe::ft::FaultPlan;
use flowmoe::runtime::Engine;
use flowmoe::trainer::{init_params, train_dp, TrainOpts};
use flowmoe::util::Rng;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tmp_ckpt_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("flowmoe_ft_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn assert_bitwise_losses(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: step {i}: {x} vs {y}");
    }
}

fn assert_bitwise_params(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: tensor count");
    for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
        assert_eq!(pa.len(), pb.len(), "{what}: tensor {i} length");
        for (j, (x, y)) in pa.iter().zip(pb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: param {i}[{j}]: {x} vs {y}");
        }
    }
}

/// The resume contract, bitwise: an uninterrupted 2N-step run and an
/// N-step run + checkpoint + fresh-process restore + N more steps must
/// produce the same loss CSV and the same final parameters bit for bit.
#[test]
fn resume_parity_bitwise() {
    let dir = artifacts();
    let ckdir = tmp_ckpt_dir("resume");
    let n = 3usize;

    let mut full = TrainOpts::new("tiny", 2 * n);
    full.seed = 17;
    let a = train_dp(&dir, 2, &full).unwrap();
    assert_eq!(a.losses.len(), 2 * n);

    let mut first = TrainOpts::new("tiny", n);
    first.seed = 17;
    first.ckpt_dir = Some(ckdir.clone());
    first.ckpt_every = n;
    let b1 = train_dp(&dir, 2, &first).unwrap();
    assert!(b1.recoveries.is_empty());

    let mut second = TrainOpts::new("tiny", n);
    second.seed = 17;
    second.ckpt_dir = Some(ckdir.clone());
    second.resume = true;
    let b2 = train_dp(&dir, 2, &second).unwrap();
    let _ = std::fs::remove_dir_all(&ckdir);

    assert_eq!(b2.start_step, n, "resume must pick up at the checkpoint step");
    assert_bitwise_losses(&a.losses[..n], &b1.losses, "first half");
    assert_bitwise_losses(&a.losses[n..], &b2.losses, "resumed half");
    assert_bitwise_params(&a.final_params, &b2.final_params, "final params");
}

/// Kill worker 2 of 3 at step 5 with checkpoints every 2 steps: the
/// survivors must detect the death as a typed error, re-shard to P-1,
/// reload the step-4 checkpoint, and finish all 8 steps. The recovered
/// segment must match a clean P-1 run resumed from a byte-identical
/// checkpoint — recovery is a restart, not an approximation.
#[test]
fn kill_recovery_matches_fresh_p_minus_1_run() {
    let dir = artifacts();
    let ck_kill = tmp_ckpt_dir("kill");
    let ck_ref = tmp_ckpt_dir("kill_ref");
    let steps = 8usize;

    let mut opts = TrainOpts::new("tiny", steps);
    opts.seed = 29;
    opts.ckpt_dir = Some(ck_kill.clone());
    opts.ckpt_every = 2;
    opts.detect_ms = 5000;
    opts.fault = Some(FaultPlan {
        seed: 7,
        kill: Some((2, 5)),
        ..FaultPlan::default()
    });
    let killed = train_dp(&dir, 3, &opts).unwrap();

    assert_eq!(killed.recoveries.len(), 1, "exactly one recovery");
    let ev = &killed.recoveries[0];
    assert_eq!(ev.failed_rank, 2);
    assert_eq!(ev.detected_step, 5);
    assert_eq!(ev.ckpt_step, 4, "newest checkpoint before the fault is step 4");
    assert_eq!(ev.steps_lost, 2, "steps 4 and 5 are re-run");
    assert_eq!(ev.p_after, 2);
    assert!(
        ev.reshard.iter().all(|ranks| !ranks.is_empty() && ranks.iter().all(|&w| w < 2)),
        "every expert must be re-assigned to a survivor: {:?}",
        ev.reshard
    );
    assert_eq!(killed.losses.len(), steps, "the run must still finish all steps");

    // A clean P=3 run of 4 steps writes the same step-4 checkpoint —
    // the fault cannot have perturbed anything before it fired.
    let mut pre = TrainOpts::new("tiny", 4);
    pre.seed = 29;
    pre.ckpt_dir = Some(ck_ref.clone());
    pre.ckpt_every = 2;
    train_dp(&dir, 3, &pre).unwrap();
    let ck_a = std::fs::read(ck_kill.join("ckpt_0000000004.bin")).unwrap();
    let ck_b = std::fs::read(ck_ref.join("ckpt_0000000004.bin")).unwrap();
    assert_eq!(ck_a, ck_b, "pre-fault checkpoints must be byte-identical");

    // Fresh P-1 continuation from that checkpoint.
    let mut rest = TrainOpts::new("tiny", 4);
    rest.seed = 29;
    rest.ckpt_dir = Some(ck_ref.clone());
    rest.resume = true;
    let fresh = train_dp(&dir, 2, &rest).unwrap();
    let _ = std::fs::remove_dir_all(&ck_kill);
    let _ = std::fs::remove_dir_all(&ck_ref);

    assert_eq!(fresh.start_step, 4);
    assert_bitwise_losses(&killed.losses[4..], &fresh.losses, "post-recovery segment");
    assert_bitwise_params(&killed.final_params, &fresh.final_params, "post-recovery params");
}

/// Regression: recovery restores the *newest valid* checkpoint, and a
/// stale directory can hold one from a longer earlier run whose step is
/// already past this run's target. The remaining-steps math must then
/// be a clean zero-step no-op (finish with the checkpoint's params) —
/// not an underflow that panics or spins the workers on a wrapped-around
/// step count.
#[test]
fn recovery_from_checkpoint_past_target_is_clean_noop() {
    let dir = artifacts();
    let ckdir = tmp_ckpt_dir("stale_newer");

    // A longer earlier run leaves checkpoints at steps 2, 4 and 6.
    let mut long = TrainOpts::new("tiny", 6);
    long.seed = 41;
    long.ckpt_dir = Some(ckdir.clone());
    long.ckpt_every = 2;
    train_dp(&dir, 3, &long).unwrap();
    let ck6 = flowmoe::ft::latest_valid(&ckdir).unwrap().expect("step-6 checkpoint").1;
    assert_eq!(ck6.step, 6);

    // A shorter rerun against the same directory targets step 4 and is
    // killed at step 3: recovery scans the directory, finds step 6 — a
    // checkpoint *past* the target — and must finish as a no-op.
    let mut short = TrainOpts::new("tiny", 4);
    short.seed = 41;
    short.ckpt_dir = Some(ckdir.clone());
    short.ckpt_every = 0; // never write: keep step 6 the newest
    short.detect_ms = 5000;
    short.fault = Some(FaultPlan {
        seed: 13,
        kill: Some((2, 3)),
        ..FaultPlan::default()
    });
    let report = train_dp(&dir, 3, &short).unwrap();
    let _ = std::fs::remove_dir_all(&ckdir);

    assert_eq!(report.recoveries.len(), 1, "exactly one recovery");
    let ev = &report.recoveries[0];
    assert_eq!(ev.detected_step, 3);
    assert_eq!(ev.ckpt_step, 6, "the stale step-6 checkpoint is the newest valid one");
    assert_eq!(ev.steps_lost, 0, "nothing re-run: the checkpoint is ahead of the fault");
    assert_bitwise_params(
        &report.final_params,
        &ck6.params,
        "no-op run must finish with the checkpoint's params",
    );
}

/// `--resume --steps 0` (target == checkpoint step) is the boundary of
/// the same math: zero remaining steps, empty loss CSV, checkpoint
/// params returned untouched.
#[test]
fn resume_with_zero_steps_is_clean_noop() {
    let dir = artifacts();
    let ckdir = tmp_ckpt_dir("resume_zero");

    let mut first = TrainOpts::new("tiny", 3);
    first.seed = 23;
    first.ckpt_dir = Some(ckdir.clone());
    first.ckpt_every = 3;
    train_dp(&dir, 2, &first).unwrap();
    let ck = flowmoe::ft::latest_valid(&ckdir).unwrap().expect("step-3 checkpoint").1;
    assert_eq!(ck.step, 3);

    let mut zero = TrainOpts::new("tiny", 0);
    zero.seed = 23;
    zero.ckpt_dir = Some(ckdir.clone());
    zero.resume = true;
    let report = train_dp(&dir, 2, &zero).unwrap();
    let _ = std::fs::remove_dir_all(&ckdir);

    assert_eq!(report.start_step, 3, "resume picks up at the checkpoint step");
    assert!(report.losses.is_empty(), "zero steps requested, zero steps run");
    assert!(report.recoveries.is_empty());
    assert_bitwise_params(&report.final_params, &ck.params, "params pass through untouched");
}

/// Hang-class regression on the EP cluster path: a worker killed before
/// the dispatch A2A must surface as a typed error within the detection
/// window — the survivors' `a2a recv` calls error out instead of
/// blocking forever.
#[test]
fn ep_cluster_kill_surfaces_typed_error_within_deadline() {
    let dir = artifacts();
    let engine = Engine::new(&dir).unwrap();
    let p = 2;
    let geo = ep_geometry(&engine, "tiny", p).unwrap();
    let params = init_params(&engine, "tiny", 55).unwrap();
    let bp = &params[1..10];
    let atp: Vec<Vec<f32>> = bp[..7].to_vec();
    let (w1_full, w2_full) = (bp[7].clone(), bp[8].clone());

    let mut rng = Rng::new(77);
    let t_m = geo.t * geo.m;
    let xs: Vec<Vec<f32>> = (0..p)
        .map(|_| (0..t_m).map(|_| rng.normal() as f32 * 0.5).collect())
        .collect();
    let dys: Vec<Vec<f32>> = (0..p)
        .map(|_| (0..t_m).map(|_| rng.normal() as f32 * 0.5).collect())
        .collect();

    let t0 = Instant::now();
    let err = run_ep_cluster_faulty(
        &dir,
        "tiny",
        p,
        atp,
        w1_full,
        w2_full,
        xs,
        dys,
        Some(FaultPlan {
            seed: 3,
            kill: Some((1, 0)),
            ..FaultPlan::default()
        }),
        3000,
    )
    .unwrap_err();
    let waited = t0.elapsed();

    let msg = format!("{err:#}");
    assert!(
        msg.contains("killed") || msg.contains("dead") || msg.contains("a2a recv"),
        "expected a typed kill/peer-dead error, got: {msg}"
    );
    assert!(
        waited < Duration::from_secs(30),
        "detection took {waited:?}, deadline semantics are broken"
    );
}
