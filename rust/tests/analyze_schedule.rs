//! Integration tests for the static verification layer (`flowmoe
//! analyze`): the whole Fig. 6 customized-layer grid must be violation-
//! free under the full policy matrix, and the static analyzer must agree
//! with the dynamic pair (`simulate` + `verify_timeline`) — clean DAGs
//! pass both, seeded mutations are caught by the static pass (and, where
//! the mutation breaks structural invariants, by the simulator's debug
//! pre-flight as well).

use std::panic::{catch_unwind, AssertUnwindSafe};

use flowmoe::analyze::{check_dag, check_schedule, policy_matrix, Rule};
use flowmoe::config::{preset, table2_models, ClusterProfile};
use flowmoe::cost::TaskCosts;
use flowmoe::sched::{build_dag, Policy};
use flowmoe::sim::{simulate, verify_timeline};
use flowmoe::sweep::{custom_layer_grid, Sweeper};
use flowmoe::tasks::{Dag, Stream, TaskKind};

const GPUS: usize = 16;
const SP: f64 = 2.5e6;

/// The full Fig. 6 grid (675 customized MoE layers) x all 11 policies is
/// statically clean — the same exhaustive pass CI runs through the
/// `flowmoe analyze --grid fig6` subcommand, here on the sweep engine.
#[test]
fn fig6_grid_is_clean_under_every_policy() {
    let cl = ClusterProfile::cluster1(GPUS);
    let grid = custom_layer_grid(GPUS);
    assert_eq!(grid.len(), 675, "Fig. 6 grid size");
    let pols = policy_matrix(2, SP);
    assert_eq!(pols.len(), 11, "policy matrix size");
    let sweeper = Sweeper::new();
    let bad: Vec<String> = sweeper
        .run(&grid, |i, cfg| {
            let costs = TaskCosts::build(cfg, &cl);
            let mut msgs = Vec::new();
            for pol in &pols {
                let (_, vs) = check_schedule(cfg, &costs, pol);
                for v in vs {
                    msgs.push(format!("config {i} under {}: {v}", pol.name));
                }
            }
            msgs
        })
        .into_iter()
        .flatten()
        .collect();
    assert!(bad.is_empty(), "{} violation(s); first: {}", bad.len(), bad[0]);
}

/// The paper's Table 2 presets (multi-layer DAGs, unlike the l=1 grid)
/// are clean under every policy and several (r, S_p) settings.
#[test]
fn table2_presets_are_clean_under_every_policy() {
    let cl = ClusterProfile::cluster1(GPUS);
    let mut cfgs = table2_models();
    cfgs.extend(["tiny", "e2e"].iter().filter_map(|&n| preset(n)));
    for cfg in &cfgs {
        let costs = TaskCosts::build(cfg, &cl);
        for (r, sp) in [(1, SP), (2, SP), (4, 0.7e6)] {
            for pol in policy_matrix(r, sp) {
                let (_, vs) = check_schedule(cfg, &costs, &pol);
                assert!(
                    vs.is_empty(),
                    "{} under {} (r={r}, sp={sp}): {}",
                    cfg.name,
                    pol.name,
                    vs[0]
                );
            }
        }
    }
}

fn fixture() -> (Dag, Policy) {
    let cfg = preset("GPT2-Tiny-MoE").expect("preset");
    let cl = ClusterProfile::cluster1(GPUS);
    let costs = TaskCosts::build(&cfg, &cl);
    let pol = Policy::flow_moe(2, SP);
    (build_dag(&cfg, &costs, &pol), pol)
}

fn rules_of(dag: &Dag, pol: &Policy) -> Vec<Rule> {
    check_dag(dag, pol).iter().map(|v| v.rule).collect()
}

/// Clean DAG: static verifier and dynamic verifier both pass.
#[test]
fn verifiers_agree_on_clean_dag() {
    let (dag, pol) = fixture();
    assert!(check_dag(&dag, &pol).is_empty());
    let tl = simulate(&dag);
    verify_timeline(&dag, &tl).expect("dynamic verification");
}

/// Cycle mutation: the static pass reports S002, and the simulator's
/// debug-build pre-flight (which calls the structural half of the same
/// analyzer) refuses the DAG instead of deadlocking.
#[test]
fn cycle_mutation_caught_by_both_verifiers() {
    let (mut dag, pol) = fixture();
    let last = dag.tasks.len() - 1;
    dag.tasks[0].deps.push(last);
    let rules = rules_of(&dag, &pol);
    assert!(rules.contains(&Rule::Cycle), "static: {rules:?}");
    let r = catch_unwind(AssertUnwindSafe(|| simulate(&dag)));
    assert!(r.is_err(), "debug pre-flight must reject a cyclic DAG");
}

/// Stream-legality mutation is a *policy* violation: the static pass
/// flags it, while the dynamic pair still passes (the simulator will
/// happily schedule a compute task on a comm stream).
#[test]
fn stream_mutation_caught_only_statically() {
    let (mut dag, pol) = fixture();
    let at = dag
        .tasks
        .iter()
        .position(|t| matches!(t.kind, TaskKind::At { .. }))
        .expect("an AT task");
    dag.tasks[at].stream = Stream::Comm;
    let rules = rules_of(&dag, &pol);
    assert!(rules.contains(&Rule::StreamLegality), "static: {rules:?}");
    let tl = simulate(&dag);
    verify_timeline(&dag, &tl).expect("dynamic pass still accepts it");
}

/// AR partition mutation (a chunk shrunk to half size, so the chunks no
/// longer cover the tensor): statically an S006, dynamically invisible.
#[test]
fn ar_partition_mutation_caught_only_statically() {
    let (mut dag, pol) = fixture();
    let ar = dag
        .tasks
        .iter()
        .position(|t| matches!(t.kind, TaskKind::Ar { .. }))
        .expect("an AR task");
    dag.tasks[ar].bytes *= 0.5;
    let rules = rules_of(&dag, &pol);
    assert!(rules.contains(&Rule::ArChunks), "static: {rules:?}");
    let tl = simulate(&dag);
    verify_timeline(&dag, &tl).expect("dynamic pass still accepts it");
}

/// AR priority inversion (two chunk seqs swapped): statically an S006;
/// the simulator's debug pre-flight also rejects it, because AR FIFO
/// discipline is part of the structural contract `simulate` assumes.
#[test]
fn ar_priority_inversion_caught_by_both_verifiers() {
    let (mut dag, pol) = fixture();
    let ars: Vec<usize> = dag
        .tasks
        .iter()
        .enumerate()
        .filter(|(_, t)| matches!(t.kind, TaskKind::Ar { .. }))
        .map(|(i, _)| i)
        .take(2)
        .collect();
    assert_eq!(ars.len(), 2, "need two AR chunks");
    let (s0, s1) = (dag.tasks[ars[0]].seq, dag.tasks[ars[1]].seq);
    dag.tasks[ars[0]].seq = s1;
    dag.tasks[ars[1]].seq = s0;
    let rules = rules_of(&dag, &pol);
    assert!(rules.contains(&Rule::ArChunks), "static: {rules:?}");
    // the pre-flight only runs under debug_assertions; in release the
    // inverted seqs simulate fine (they only reorder the AR stream)
    if cfg!(debug_assertions) {
        let r = catch_unwind(AssertUnwindSafe(|| simulate(&dag)));
        assert!(r.is_err(), "debug pre-flight must reject AR seq inversion");
    }
}

/// Orphan-task mutation: statically an S007 (connectivity), dynamically
/// invisible (the extra task simply runs).
#[test]
fn orphan_mutation_caught_only_statically() {
    let (mut dag, pol) = fixture();
    let id = dag.tasks.len();
    dag.tasks.push(flowmoe::tasks::Task {
        id,
        kind: TaskKind::Exp { l: 0, r: 0, phase: flowmoe::tasks::Phase::Fwd },
        stream: Stream::Compute,
        dur: 1e-5,
        deps: Vec::new(),
        seq: 3,
        bytes: 0.0,
    });
    let rules = rules_of(&dag, &pol);
    assert!(rules.contains(&Rule::Connectivity), "static: {rules:?}");
    let tl = simulate(&dag);
    verify_timeline(&dag, &tl).expect("dynamic pass still accepts it");
}
