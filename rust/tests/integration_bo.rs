//! BO autotuner integration against the simulated objective (Fig. 4 /
//! Tables A.3–A.5 shapes).

use flowmoe::bo::{grid_search, random_tuner, Acquisition, BoTuner, Kernel};
use flowmoe::config::{preset, ClusterProfile};
use flowmoe::sched::{iteration_time, Policy};

fn objective(model: &str) -> impl Fn(f64) -> f64 + '_ {
    let cfg = preset(model).unwrap();
    let cl = ClusterProfile::cluster1(16);
    move |sp: f64| iteration_time(&cfg, &cl, &Policy::flow_moe(2, sp)).0
}

#[test]
fn fig4_bo_finds_low_iteration_time_on_bert() {
    let cfg = preset("BERT-Large-MoE").unwrap();
    let obj = objective("BERT-Large-MoE");
    let max = cfg.ar_bytes_per_block();
    let mut bo = BoTuner::new(max, 42);
    let best = bo.tune(8, &obj);
    // BO-with-8-samples must be within 3% of a dense grid optimum.
    let mut dense_best = f64::INFINITY;
    for i in 1..=100 {
        dense_best = dense_best.min(obj(max * i as f64 / 100.0));
    }
    let got = obj(best);
    assert!(
        got <= dense_best * 1.03,
        "BO {got:.5} vs dense grid {dense_best:.5} (best sp {:.2}MB)",
        best / 1e6
    );
}

#[test]
fn tableA3_bo_beats_grid_and_random_on_average() {
    // Across the four models, BO's tuned time must be <= grid-search's
    // and strictly better than random sampling's average.
    let mut bo_total = 0.0;
    let mut grid_total = 0.0;
    let mut rand_total = 0.0;
    for model in ["GPT2-Tiny-MoE", "BERT-Large-MoE", "LLaMA2-MoE", "DeepSeek-V2-S"] {
        let cfg = preset(model).unwrap();
        let obj = objective(model);
        let max = cfg.ar_bytes_per_block();
        let mut bo = BoTuner::new(max, 7);
        let b = bo.tune(8, &obj);
        bo_total += obj(b);
        let g = grid_search(max, 8, &obj);
        grid_total += obj(g);
        let (_, avg) = random_tuner(max, 8, 7, &obj);
        rand_total += avg;
    }
    assert!(
        bo_total <= grid_total * 1.02,
        "BO {bo_total:.4} vs grid {grid_total:.4}"
    );
    assert!(bo_total < rand_total, "BO {bo_total:.4} vs random {rand_total:.4}");
}

#[test]
fn tableA4_bo_beats_every_fixed_sp() {
    for model in ["BERT-Large-MoE", "LLaMA2-MoE"] {
        let cfg = preset(model).unwrap();
        let obj = objective(model);
        let mut bo = BoTuner::new(cfg.ar_bytes_per_block(), 11);
        let tuned = obj(bo.tune(8, &obj));
        for sp_mb in [0.5, 1.0, 2.0, 4.0, 8.0] {
            let fixed = obj(sp_mb * 1e6);
            assert!(
                tuned <= fixed * 1.02,
                "{model}: tuned {tuned:.4} vs fixed {sp_mb}MB {fixed:.4}"
            );
        }
    }
}

#[test]
fn tableA5_hyperparameters_all_converge_similarly() {
    // Appendix D: BO is insensitive to acquisition/kernel choices on this
    // single-peaked objective — all configs within 5 % of the best.
    let cfg = preset("BERT-Large-MoE").unwrap();
    let obj = objective("BERT-Large-MoE");
    let max = cfg.ar_bytes_per_block();
    let mut results = Vec::new();
    let configs: Vec<(Acquisition, Kernel)> = vec![
        (Acquisition::Ei { xi: 0.1 }, Kernel::Matern52 { len: 0.25 }),
        (Acquisition::Ei { xi: 0.05 }, Kernel::Matern52 { len: 0.25 }),
        (Acquisition::Ei { xi: 0.2 }, Kernel::Matern52 { len: 0.25 }),
        (Acquisition::Pi { xi: 0.1 }, Kernel::Matern52 { len: 0.25 }),
        (Acquisition::Lcb { kappa: 2.0 }, Kernel::Matern52 { len: 0.25 }),
        (Acquisition::Ei { xi: 0.1 }, Kernel::Rbf { len: 0.25 }),
        (
            Acquisition::Ei { xi: 0.1 },
            Kernel::RationalQuadratic { len: 0.25, alpha: 1.0 },
        ),
    ];
    for (acq, kern) in configs {
        let mut bo = BoTuner::new(max, 5).with_acquisition(acq).with_kernel(kern);
        let best = bo.tune(10, &obj);
        results.push(obj(best));
    }
    let best = results.iter().copied().fold(f64::INFINITY, f64::min);
    for (i, r) in results.iter().enumerate() {
        assert!(r / best < 1.05, "config {i}: {r:.4} vs best {best:.4}");
    }
}

#[test]
fn retune_trigger_appendix_k2() {
    // Simulated hardware change (halved AR bandwidth) must trip Eq. A.11.
    let cfg = preset("BERT-Large-MoE").unwrap();
    let cl = ClusterProfile::cluster1(16);
    let mut bo = BoTuner::new(cfg.ar_bytes_per_block(), 3);
    let best_sp = bo.tune(8, |sp| iteration_time(&cfg, &cl, &Policy::flow_moe(2, sp)).0);
    let tuned_t = bo.best().unwrap().1;

    let mut degraded = cl.clone();
    degraded.net.ar_bw *= 0.3;
    degraded.net.inter_bw *= 0.3;
    let new_t = iteration_time(&cfg, &degraded, &Policy::flow_moe(2, best_sp)).0;
    assert!(flowmoe::bo::should_retune(new_t, tuned_t, 0.1));
    // and after re-tuning on the new hardware, time improves vs stale S_p
    let mut bo2 = BoTuner::new(cfg.ar_bytes_per_block(), 9);
    let new_sp = bo2.tune(8, |sp| iteration_time(&cfg, &degraded, &Policy::flow_moe(2, sp)).0);
    let retuned_t = iteration_time(&cfg, &degraded, &Policy::flow_moe(2, new_sp)).0;
    assert!(retuned_t <= new_t * 1.001, "retuned {retuned_t} vs stale {new_t}");
}
