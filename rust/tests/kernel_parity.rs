//! Parity and determinism contracts of the blocked/parallel native
//! kernels (§Perf):
//!
//! * blocked matmuls agree with the naive `*_ref` oracles within 1e-4
//!   rel-tol across odd/prime/irregular shapes,
//! * parallel (M-banded / expert-banded) execution is **byte-identical**
//!   to serial for any thread budget,
//! * `Workspace` reuse (dirty recycled buffers) is byte-identical to
//!   fresh allocation, across consecutive `train_step` calls.

use flowmoe::backend::kernels as kn;
use flowmoe::backend::model as nm;
use flowmoe::backend::Workspace;
use flowmoe::config::preset;
use flowmoe::sweep::scope;
use flowmoe::util::Rng;

fn randv(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * s).collect()
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[track_caller]
fn assert_rel_close(got: &[f32], want: &[f32], rel: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = rel * (g.abs() + w.abs()) + 1e-5;
        assert!((g - w).abs() <= tol, "{what}[{i}]: {g} vs {w}");
    }
}

/// The satellite contract: blocked kernels vs the naive reference across
/// every (m, k, n) in {1, 3, 17, 64, 100}^3 — odd, prime, tile-aligned
/// and remainder-heavy shapes — within 1e-4 relative tolerance.
#[test]
fn blocked_matmuls_match_reference_across_odd_shapes() {
    let dims = [1usize, 3, 17, 64, 100];
    let mut rng = Rng::new(2024);
    for &m in &dims {
        for &k in &dims {
            for &n in &dims {
                let a = randv(&mut rng, m * k, 1.0);
                let b = randv(&mut rng, k * n, 1.0);
                assert_rel_close(
                    &kn::matmul(&a, &b, m, k, n),
                    &kn::matmul_ref(&a, &b, m, k, n),
                    1e-4,
                    &format!("matmul {m}x{k}x{n}"),
                );
                let bt = randv(&mut rng, n * k, 1.0);
                assert_rel_close(
                    &kn::matmul_nt(&a, &bt, m, k, n),
                    &kn::matmul_nt_ref(&a, &bt, m, k, n),
                    1e-4,
                    &format!("matmul_nt {m}x{k}x{n}"),
                );
                let at = randv(&mut rng, k * m, 1.0);
                assert_rel_close(
                    &kn::matmul_tn(&at, &b, k, m, n),
                    &kn::matmul_tn_ref(&at, &b, k, m, n),
                    1e-4,
                    &format!("matmul_tn {m}x{k}x{n}"),
                );
            }
        }
    }
}

/// Parallel row-banding must not change a single bit, for any budget.
/// Shapes sit above the kernels' parallel work threshold so the banded
/// path really runs when the budget allows it.
#[test]
fn parallel_matmuls_byte_identical_across_budgets() {
    let mut rng = Rng::new(7);
    for &(m, k, n) in &[(64usize, 64usize, 64usize), (101, 53, 67)] {
        let a = randv(&mut rng, m * k, 1.0);
        let b = randv(&mut rng, k * n, 1.0);
        let bt = randv(&mut rng, n * k, 1.0);
        let at = randv(&mut rng, k * m, 1.0);
        let s_mm = scope::with_budget(1, || kn::par_matmul(&a, &b, m, k, n));
        let s_nt = scope::with_budget(1, || kn::par_matmul_nt(&a, &bt, m, k, n));
        let s_tn = scope::with_budget(1, || kn::par_matmul_tn(&at, &b, k, m, n));
        for budget in [2usize, 3, 5, 16] {
            scope::with_budget(budget, || {
                assert!(bits_eq(&s_mm, &kn::par_matmul(&a, &b, m, k, n)), "mm b={budget}");
                assert!(bits_eq(&s_nt, &kn::par_matmul_nt(&a, &bt, m, k, n)), "nt b={budget}");
                assert!(bits_eq(&s_tn, &kn::par_matmul_tn(&at, &b, k, m, n)), "tn b={budget}");
            });
        }
    }
}

/// Expert-axis fan-out of the FFN (fwd + bwd) must be byte-identical to
/// the serial loop. Shapes exceed the per-expert parallel threshold.
#[test]
fn parallel_expert_ffn_byte_identical_across_budgets() {
    let (e, c, m, h) = (4usize, 32usize, 32usize, 256usize);
    let mut rng = Rng::new(9);
    let x = randv(&mut rng, e * c * m, 0.7);
    let w1 = randv(&mut rng, e * m * h, 0.4);
    let w2 = randv(&mut rng, e * h * m, 0.4);
    let dy = randv(&mut rng, e * c * m, 1.0);
    let fwd_s = scope::with_budget(1, || kn::expert_ffn(&x, &w1, &w2, e, c, m, h));
    let (dx_s, dw1_s, dw2_s) = scope::with_budget(1, || kn::expert_ffn_bwd(&x, &w1, &w2, &dy, e, c, m, h));
    for budget in [2usize, 4, 8] {
        scope::with_budget(budget, || {
            assert!(bits_eq(&fwd_s, &kn::expert_ffn(&x, &w1, &w2, e, c, m, h)), "fwd b={budget}");
            let (dx, dw1, dw2) = kn::expert_ffn_bwd(&x, &w1, &w2, &dy, e, c, m, h);
            assert!(bits_eq(&dx_s, &dx), "dx b={budget}");
            assert!(bits_eq(&dw1_s, &dw1), "dw1 b={budget}");
            assert!(bits_eq(&dw2_s, &dw2), "dw2 b={budget}");
        });
    }
}

/// The per-(sample, head) MHA fan-out must be byte-identical to the
/// serial head loop. The geometry clears the head-parallel threshold
/// (units * N^2 * hd) while staying cheap.
#[test]
fn parallel_mha_heads_byte_identical_across_budgets() {
    let g = nm::Geo {
        m: 32,
        e: 4,
        h: 16,
        top_k: 2,
        n_heads: 4,
        n_seq: 32,
        f: 4.0,
        vocab: 64,
    };
    let mut rng = Rng::new(11);
    let params: Vec<Vec<f32>> = vec![
        vec![1.0; g.m],                       // n1
        randv(&mut rng, g.m * g.m, 0.3),      // wq
        randv(&mut rng, g.m * g.m, 0.3),      // wk
        randv(&mut rng, g.m * g.m, 0.3),      // wv
        randv(&mut rng, g.m * g.m, 0.3),      // wo
        vec![1.0; g.m],                       // n2
        randv(&mut rng, g.m * g.e, 0.5),      // wg
    ];
    let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
    let atp = nm::AtParams::new(&refs);
    let b = 4usize;
    let x = randv(&mut rng, b * g.n_seq * g.m, 0.5);
    let dh = randv(&mut rng, x.len(), 1.0);
    let (h_s, grads_s, dx_s) = scope::with_budget(1, || {
        let st = nm::mha_forward(&g, &atp, &x);
        let (grads, dx) = nm::mha_backward(&g, &atp, &x, &st, &dh);
        (st.h, grads, dx)
    });
    for budget in [2usize, 4] {
        scope::with_budget(budget, || {
            let st = nm::mha_forward(&g, &atp, &x);
            assert!(bits_eq(&h_s, &st.h), "h b={budget}");
            let (grads, dx) = nm::mha_backward(&g, &atp, &x, &st, &dh);
            assert!(bits_eq(&dx_s, &dx), "dx b={budget}");
            for (i, (gp, gs)) in grads.iter().zip(&grads_s).enumerate() {
                assert!(bits_eq(gs, gp), "grad {i} b={budget}");
            }
        });
    }
}

/// The workspace satellite contract: two consecutive `train_step` calls
/// through one shared (dirty) workspace produce bit-identical losses and
/// parameters — and match the fresh-allocation wrapper exactly.
#[test]
fn workspace_reuse_bit_identical_train_steps() {
    let g = nm::Geo::from_cfg(&preset("tiny").unwrap());
    let mut rng = Rng::new(17);
    let mut shapes: Vec<usize> = vec![g.vocab * g.m];
    shapes.extend([
        g.m,
        g.m * g.m,
        g.m * g.m,
        g.m * g.m,
        g.m * g.m,
        g.m,
        g.m * g.e,
        g.e * g.m * g.h,
        g.e * g.h * g.m,
    ]);
    shapes.push(g.m);
    let params: Vec<Vec<f32>> = shapes.iter().map(|&n| randv(&mut rng, n, 0.15)).collect();
    let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
    let moms: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let mrefs: Vec<&[f32]> = moms.iter().map(|v| v.as_slice()).collect();
    let b = 2usize;
    let tokens: Vec<i32> = (0..b * g.n_seq).map(|_| rng.below(g.vocab) as i32).collect();
    let lr = 0.05f32;

    let (p_fresh, m_fresh, loss_fresh) = nm::train_step(&g, &refs, &mrefs, &tokens, lr, b);
    let mut ws = Workspace::new();
    let (p1, m1, loss1) = nm::train_step_ws(&g, &refs, &mrefs, &tokens, lr, b, &mut ws);
    assert!(ws.pooled() > 0, "workspace retired no buffers");
    // second call re-runs the same step on the now-dirty pool
    let (p2, m2, loss2) = nm::train_step_ws(&g, &refs, &mrefs, &tokens, lr, b, &mut ws);
    assert_eq!(loss1.to_bits(), loss2.to_bits(), "losses differ across reuse");
    assert_eq!(loss1.to_bits(), loss_fresh.to_bits(), "ws loss differs from fresh");
    for i in 0..p1.len() {
        assert!(bits_eq(&p1[i], &p2[i]), "params {i} differ across reuse");
        assert!(bits_eq(&p1[i], &p_fresh[i]), "params {i} differ from fresh");
        assert!(bits_eq(&m1[i], &m2[i]), "moms {i} differ across reuse");
        assert!(bits_eq(&m1[i], &m_fresh[i]), "moms {i} differ from fresh");
    }
}

/// Full grad_step must be deterministic and budget-independent on the
/// tiny config (covers gating, routing, heads, experts, head loss).
#[test]
fn grad_step_byte_identical_across_budgets() {
    let g = nm::Geo::from_cfg(&preset("tiny").unwrap());
    let mut rng = Rng::new(23);
    let mut shapes: Vec<usize> = vec![g.vocab * g.m];
    for _ in 0..2 {
        shapes.extend([
            g.m,
            g.m * g.m,
            g.m * g.m,
            g.m * g.m,
            g.m * g.m,
            g.m,
            g.m * g.e,
            g.e * g.m * g.h,
            g.e * g.h * g.m,
        ]);
    }
    shapes.push(g.m);
    let params: Vec<Vec<f32>> = shapes.iter().map(|&n| randv(&mut rng, n, 0.15)).collect();
    let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
    let b = 2usize;
    let tokens: Vec<i32> = (0..b * g.n_seq).map(|_| rng.below(g.vocab) as i32).collect();
    let (loss_s, grads_s) = scope::with_budget(1, || nm::grad_step(&g, &refs, &tokens, b));
    for budget in [2usize, 4] {
        let (loss, grads) = scope::with_budget(budget, || nm::grad_step(&g, &refs, &tokens, b));
        assert_eq!(loss_s.to_bits(), loss.to_bits(), "loss b={budget}");
        for (i, (gp, gs)) in grads.iter().zip(&grads_s).enumerate() {
            assert!(bits_eq(gs, gp), "grad {i} b={budget}");
        }
    }
}
