//! BO autotuning walkthrough (paper Sec. 4.1 / Fig. 4): tune S_p for any
//! preset model, print the sample trajectory, the GP posterior, and the
//! Appendix K.2 re-tuning trigger in action on a degraded network.
//!
//! Run: `cargo run --release --example bo_tuning -- [--model NAME]`

use flowmoe::bo::{should_retune, BoTuner};
use flowmoe::cli::Args;
use flowmoe::config::{preset, ClusterProfile};
use flowmoe::sched::{iteration_time, Policy};

fn main() {
    let args = Args::from_env();
    let model = args.get_or("model", "BERT-Large-MoE");
    let cfg = preset(&model).expect("unknown model");
    let cl = ClusterProfile::cluster1(args.usize_or("gpus", 16));

    let obj = |sp: f64| iteration_time(&cfg, &cl, &Policy::flow_moe(2, sp)).0;
    let max = cfg.ar_bytes_per_block();
    println!("tuning S_p for {model} (AR tensor/block = {:.2} MB)", max / 1e6);

    let mut bo = BoTuner::new(max, args.usize_or("seed", 42) as u64);
    for i in 0..8 {
        let sp = bo.suggest();
        let t = obj(sp);
        bo.observe(sp, t);
        println!("  trial {i}: S_p = {:7.3} MB -> {:8.2} ms", sp / 1e6, t * 1e3);
    }
    let (best_sp, best_t) = bo.best().unwrap();
    println!("\nbest: S_p = {:.3} MB -> {:.2} ms", best_sp / 1e6, best_t * 1e3);

    println!("\nGP posterior across the range:");
    for i in 1..=10 {
        let sp = max * i as f64 / 10.0;
        let (mu, sigma) = bo.posterior(sp);
        println!(
            "  S_p {:7.2} MB: {:8.2} ms ± {:6.2}",
            sp / 1e6,
            mu * 1e3,
            2.0 * sigma * 1e3
        );
    }

    // Appendix K.2: simulate a network degradation and re-tune
    let mut degraded = cl.clone();
    degraded.net.ar_bw *= 0.3;
    degraded.net.inter_bw *= 0.3;
    let now = iteration_time(&cfg, &degraded, &Policy::flow_moe(2, best_sp)).0;
    println!(
        "\nnetwork degraded: iteration {:.2} ms vs tuned {:.2} ms -> retune? {}",
        now * 1e3,
        best_t * 1e3,
        should_retune(now, best_t, 0.1)
    );
    let mut bo2 = BoTuner::new(max, 7);
    let new_sp = bo2.tune(8, |sp| iteration_time(&cfg, &degraded, &Policy::flow_moe(2, sp)).0);
    println!(
        "re-tuned: S_p = {:.3} MB -> {:.2} ms",
        new_sp / 1e6,
        iteration_time(&cfg, &degraded, &Policy::flow_moe(2, new_sp)).0 * 1e3
    );
}
