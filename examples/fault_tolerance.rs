//! Appendix K robustness walkthrough: heterogeneous clusters, dynamic
//! hardware (re-tuning trigger), and node-dropout recovery simulation —
//! the expert-replica failover of Appendix K.3 modelled over the
//! simulator (a failed worker's experts are served by its replica node;
//! the cluster shrinks to P-1 and the routing table is remapped).

use flowmoe::bo::should_retune;
use flowmoe::config::{preset, ClusterProfile};
use flowmoe::report::Table;
use flowmoe::sched::{iteration_time, Policy};
use flowmoe::util::fmt_ms;

fn main() {
    let cfg = preset("BERT-Large-MoE").unwrap();

    // 1) heterogeneous cluster (Appendix K.1)
    let mut t = Table::new(
        "Appendix K.1 — heterogeneous 16-GPU cluster (half the GPUs at 0.5x speed)",
        &["cluster", "vanillaEP (ms)", "FlowMoE (ms)", "speedup"],
    );
    for (name, cl) in [
        ("homogeneous", ClusterProfile::cluster1(16)),
        ("heterogeneous", ClusterProfile::cluster1_heterogeneous(16)),
    ] {
        let van = iteration_time(&cfg, &cl, &Policy::vanilla_ep()).0 * 1e3;
        let flow = iteration_time(&cfg, &cl, &Policy::flow_moe_cc(2, 2.5e6)).0 * 1e3;
        t.row(vec![
            name.into(),
            fmt_ms(van),
            fmt_ms(flow),
            format!("{:.2}x", van / flow),
        ]);
    }
    t.print();

    // 2) dynamic hardware (Appendix K.2)
    let cl = ClusterProfile::cluster1(16);
    let tuned = iteration_time(&cfg, &cl, &Policy::flow_moe(2, 2.5e6)).0;
    let mut degraded = cl.clone();
    degraded.gpu.peak_flops *= 0.6;
    let drifted = iteration_time(&cfg, &degraded, &Policy::flow_moe(2, 2.5e6)).0;
    println!(
        "\nAppendix K.2 — compute degraded to 60%: iteration {} -> {} ms; Eq. A.11 trigger (delta=0.1): {}",
        fmt_ms(tuned * 1e3),
        fmt_ms(drifted * 1e3),
        should_retune(drifted, tuned, 0.1)
    );

    // 3) node dropout (Appendix K.3): worker 13 fails; its experts are
    // served by the replica on its partner node; the collective group
    // re-forms with P-1 ranks, the partner carries a doubled expert load.
    println!("\nAppendix K.3 — node dropout recovery:");
    let before = iteration_time(&cfg, &ClusterProfile::cluster1(16), &Policy::flow_moe_cc(2, 2.5e6)).0;
    // 15 workers; the replica worker computes 2 workers' expert share:
    // model it as a heterogeneous cluster whose slowest member runs the
    // doubled expert load (0.5x effective speed on expert tasks).
    let mut after_cl = ClusterProfile::cluster1(15);
    after_cl.gpu_overrides = vec![(12, after_cl.gpu.slowed(0.5))];
    let mut cfg15 = cfg.clone();
    cfg15.e = 30; // 2 experts/worker on the 15 survivors
    let after = iteration_time(&cfg15, &after_cl, &Policy::flow_moe_cc(2, 2.5e6)).0;
    println!("  16 healthy workers: {} ms/iter", fmt_ms(before * 1e3));
    println!(
        "  after dropout (15 workers, replica double-loaded): {} ms/iter ({:.0}% degradation, training continues)",
        fmt_ms(after * 1e3),
        (after / before - 1.0) * 100.0
    );
}
