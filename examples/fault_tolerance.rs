//! Fault-tolerant native training demo: kill a worker mid-run, watch the
//! survivors detect it, re-shard, reload the last checkpoint, and finish
//! at P-1 — on the real DP trainer, not the analytical simulator.
//!
//! The run checkpoints every `--ckpt-every` steps into a temp dir, then a
//! seeded `FaultPlan` crashes worker `--kill-rank` at step `--kill-step`.
//! Survivors see a typed `CommError::PeerDead` within `--detect-ms`,
//! abort the step, re-form the collective with P-1 ranks, re-shard the
//! casualty's experts, restore the newest valid checkpoint, and continue
//! to the requested step count. The demo prints the recovery event, the
//! loss curve (with the restart visible), and writes `BENCH_fault.json`.
//!
//! Run: `cargo run --release --example fault_tolerance --
//!       [--workers P] [--steps N] [--kill-rank W] [--kill-step K]`

use std::path::PathBuf;

use flowmoe::cli::Args;
use flowmoe::ft::FaultPlan;
use flowmoe::trainer::{train_dp, TrainOpts};

fn main() {
    let args = Args::from_env();
    let dir = PathBuf::from(
        args.get_or("artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")),
    );
    let cfg = args.get_or("config", "tiny");
    let workers = args.usize_or("workers", 3);
    let steps = args.usize_or("steps", 8);
    let ckpt_every = args.usize_or("ckpt-every", 2);
    let kill_rank = args.usize_or("kill-rank", workers - 1);
    let kill_step = args.usize_or("kill-step", 5);

    let ckpt_dir = std::env::temp_dir().join(format!("flowmoe_ft_demo_{}", std::process::id()));
    std::fs::create_dir_all(&ckpt_dir).expect("create ckpt dir");

    let mut opts = TrainOpts::new(&cfg, steps);
    opts.log_every = 0;
    opts.ckpt_dir = Some(ckpt_dir.clone());
    opts.ckpt_every = ckpt_every;
    opts.detect_ms = args.usize_or("detect-ms", 5000) as u64;
    opts.fault = Some(FaultPlan {
        seed: 7,
        kill: Some((kill_rank, kill_step)),
        ..FaultPlan::default()
    });

    eprintln!(
        "training {cfg} on {workers} workers for {steps} steps, checkpoint every \
         {ckpt_every}; worker {kill_rank} is scheduled to die at step {kill_step}"
    );
    let t0 = std::time::Instant::now();
    let rep = train_dp(&dir, workers, &opts).expect("training failed");
    let train_s = t0.elapsed().as_secs_f64();

    println!("\n== recovery events ==");
    for ev in &rep.recoveries {
        println!(
            "worker {} died at step {}; detected in {:.1} ms, re-sharded in {:.1} ms, \
             restored step-{} checkpoint in {:.1} ms; {} step(s) of work lost; \
             continuing at P={}",
            ev.failed_rank,
            ev.detected_step,
            ev.detect_ms,
            ev.reshard_ms,
            ev.ckpt_step,
            ev.restore_ms,
            ev.steps_lost,
            ev.p_after,
        );
        for (e, ranks) in ev.reshard.iter().enumerate() {
            println!("  expert {e} -> survivors {ranks:?}");
        }
    }
    assert!(
        !rep.recoveries.is_empty(),
        "the planned kill should have triggered exactly one recovery"
    );

    println!("\nstep,loss");
    for (i, l) in rep.losses.iter().enumerate() {
        println!("{},{l:.4}", rep.start_step + i);
    }
    assert_eq!(rep.losses.len(), steps, "run must finish all requested steps");

    let json = flowmoe::ft::bench_json(
        &cfg,
        7,
        workers,
        steps,
        ckpt_every,
        opts.detect_ms,
        &rep.recoveries,
        train_s,
    );
    flowmoe::testutil::scan_json(&json).expect("BENCH_fault.json must be well-formed");
    std::fs::write("BENCH_fault.json", &json).expect("write BENCH_fault.json");
    eprintln!("\nwrote BENCH_fault.json; training survived the kill at P-1");

    let _ = std::fs::remove_dir_all(&ckpt_dir);
}
