//! End-to-end driver (DESIGN.md §6 / paper Fig. A.2): train the ~109M-
//! parameter `e2e` MoE transformer (L=6, M=512, H=2048, E=8, top-1) on
//! the synthetic Zipf corpus with real compute (native backend, or AOT
//! artifacts when built) across P in-process workers, FlowMoE
//! chunked-AR overlap vs centralized AR, logging the
//! loss curve and per-step wall time. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example train_e2e -- [--steps N]
//!       [--workers P] [--config tiny|e2e] [--centralized] [--csv path]`

use std::io::Write;
use std::path::PathBuf;

use flowmoe::cli::Args;
use flowmoe::trainer::{train_dp, TrainOpts};

fn main() {
    let args = Args::from_env();
    let dir = PathBuf::from(
        args.get_or("artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")),
    );
    let cfg = args.get_or("config", "e2e");
    let steps = args.usize_or("steps", 200);
    let workers = args.usize_or("workers", 2);

    if !dir.join("manifest.txt").exists() {
        eprintln!(
            "no artifacts at {} — running on the native in-tree backend \
             (build them with `make artifacts` to use AOT HLO shapes)",
            dir.display()
        );
    }

    let mut opts = TrainOpts::new(&cfg, steps);
    opts.lr = args.f64_or("lr", 0.1) as f32;
    opts.sp_bytes = (args.f64_or("sp", 1.0) * 1e6) as usize;
    opts.overlap = !args.has_flag("centralized");
    opts.log_every = args.usize_or("log-every", 5);
    opts.seed = args.usize_or("seed", 1234) as u64;

    let total_params = flowmoe::config::preset(&cfg)
        .map(|c| c.total_params())
        .unwrap_or(0);
    eprintln!(
        "training {cfg} ({:.1}M params) on {workers} workers, {steps} steps, \
         {} AR (S_p = {:.1} MB)",
        total_params as f64 / 1e6,
        if opts.overlap { "overlapped chunked" } else { "centralized" },
        opts.sp_bytes as f64 / 1e6,
    );
    let t0 = std::time::Instant::now();
    let rep = train_dp(&dir, workers, &opts).expect("training failed");
    let wall = t0.elapsed().as_secs_f64();

    println!("step,loss,step_seconds");
    let mut csv = String::new();
    for (i, (l, s)) in rep.losses.iter().zip(&rep.step_secs).enumerate() {
        let line = format!("{i},{l:.4},{s:.3}");
        println!("{line}");
        csv.push_str(&line);
        csv.push('\n');
    }
    if let Some(path) = args.get("csv") {
        std::fs::File::create(path)
            .and_then(|mut f| f.write_all(csv.as_bytes()))
            .expect("write csv");
        eprintln!("wrote {path}");
    }
    let n = rep.losses.len();
    let head: f32 = rep.losses[..(n / 10).max(1)].iter().sum::<f32>() / (n / 10).max(1) as f32;
    let tail: f32 =
        rep.losses[n - (n / 10).max(1)..].iter().sum::<f32>() / (n / 10).max(1) as f32;
    eprintln!(
        "\nloss {head:.4} -> {tail:.4} over {n} steps; {:.2}s/step median; {wall:.0}s total",
        flowmoe::util::median(&rep.step_secs)
    );
}
