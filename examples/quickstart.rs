//! Quickstart: simulate one training iteration of BERT-Large-MoE under
//! every scheduling framework, print the paper-style comparison, then run
//! a few *real* distributed training steps on the tiny config (native
//! backend or AOT artifacts + real collectives) to show the full stack
//! composing.
//!
//! Run: `cargo run --release --example quickstart`

use std::path::PathBuf;

use flowmoe::config::{preset, ClusterProfile};
use flowmoe::metrics::{energy_joules, peak_memory, sm_utilization};
use flowmoe::report::Table;
use flowmoe::sched::{build_dag, Policy};
use flowmoe::sim::simulate;
use flowmoe::trainer::{train_dp, TrainOpts};
use flowmoe::util::fmt_ms;

fn main() {
    // ---- 1) simulated comparison (the paper's Table 3 row) ----
    let cfg = preset("BERT-Large-MoE").unwrap();
    let cl = ClusterProfile::cluster1(16);
    let costs = flowmoe::cost::TaskCosts::build(&cfg, &cl);
    let mut t = Table::new(
        "BERT-Large-MoE, Cluster 1 (2x8 RTX3090), 16 GPUs, R=2",
        &["framework", "iter (ms)", "speedup", "energy (J)", "memory (GB)", "compute util"],
    );
    let mut base = 0.0;
    for pol in [
        Policy::vanilla_ep(),
        Policy::faster_moe(2),
        Policy::tutel(2),
        Policy::sche_moe(2),
        Policy::fs_moe(2),
        Policy::flow_moe(2, 2.5e6),
        Policy::flow_moe_cc(2, 2.5e6),
    ] {
        let dag = build_dag(&cfg, &costs, &pol);
        let tl = simulate(&dag);
        if pol.name == "vanillaEP" {
            base = tl.makespan;
        }
        t.row(vec![
            pol.name.into(),
            fmt_ms(tl.makespan * 1e3),
            format!("{:.2}x", base / tl.makespan),
            format!("{:.1}", energy_joules(&tl, &cl.power)),
            format!("{:.2}", peak_memory(&cfg, &cl, &pol, &dag, &tl) / 1e9),
            format!("{:.1}%", sm_utilization(&tl) * 100.0),
        ]);
    }
    t.print();

    // ---- 2) real distributed steps (native backend or AOT artifacts) ----
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("\n(no artifacts found: running on the native in-tree backend)");
    }
    println!("\nLive: 2-worker data-parallel training (tiny config, FlowMoE chunked-AR overlap)...");
    let mut opts = TrainOpts::new("tiny", 6);
    opts.log_every = 1;
    let rep = train_dp(&dir, 2, &opts).expect("training failed");
    println!(
        "loss {:.4} -> {:.4} over {} steps ({:.2}s/step median)",
        rep.losses.first().unwrap(),
        rep.losses.last().unwrap(),
        rep.losses.len(),
        flowmoe::util::median(&rep.step_secs)
    );
}
