//! The paper's customized-MoE-layer sweep (Fig. 6): B x f x N x M x H
//! grid with OOM filtering, FlowMoE-vs-ScheMoE speedup histogram on both
//! clusters.
//!
//! Run: `cargo run --release --example sweep_custom_layers -- [--limit N]`

use flowmoe::cli::Args;
use flowmoe::config::{ClusterProfile, ModelCfg};
use flowmoe::report::histogram;
use flowmoe::sched::{iteration_time, Policy};

fn main() {
    let args = Args::from_env();
    let limit = args.usize_or("limit", usize::MAX);
    for (cl, gpus) in [(ClusterProfile::cluster1(16), 16usize), (ClusterProfile::cluster2(8), 8)] {
        let mut speedups = Vec::new();
        let mut oom = 0usize;
        let mut wins = 0usize;
        'outer: for b in [2usize, 4, 8] {
            for f in [1.0, 1.1, 1.2] {
                for n in [512usize, 1024, 2048] {
                    for m in [512usize, 1024, 2048, 4096, 8192] {
                        for h in [512usize, 1024, 2048, 4096, 8192] {
                            if speedups.len() >= limit {
                                break 'outer;
                            }
                            let cfg = ModelCfg::custom_layer(b, f, n, m, h, gpus);
                            if flowmoe::cost::peak_memory_bytes(&cfg, gpus, 1.0, 1.0) > cl.mem_bytes {
                                oom += 1;
                                continue;
                            }
                            let sche = iteration_time(&cfg, &cl, &Policy::sche_moe(2)).0;
                            let flow = [1e6, 4e6, 16e6, 64e6]
                                .iter()
                                .map(|&sp| iteration_time(&cfg, &cl, &Policy::flow_moe_cc(2, sp)).0)
                                .fold(f64::INFINITY, f64::min);
                            if flow < sche {
                                wins += 1;
                            }
                            speedups.push(sche / flow);
                        }
                    }
                }
            }
        }
        println!(
            "{}",
            histogram(
                &format!(
                    "{} x{gpus}: FlowMoE/ScheMoE speedup over {} valid layers ({oom} OOM, win rate {:.0}%)",
                    cl.name,
                    speedups.len(),
                    100.0 * wins as f64 / speedups.len().max(1) as f64
                ),
                &speedups,
                12,
                40
            )
        );
        println!("mean speedup: {:.3} (paper: 1.26)", flowmoe::util::mean(&speedups));
    }
}
