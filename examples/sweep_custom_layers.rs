//! The paper's customized-MoE-layer sweep (Fig. 6): B x f x N x M x H
//! grid with OOM filtering, FlowMoE-vs-ScheMoE speedup histogram on both
//! clusters — evaluated on the multi-core `flowmoe::sweep` engine with a
//! live progress/ETA readout.
//!
//! Run: `cargo run --release --example sweep_custom_layers -- [--limit N]
//!       [--threads T]`

use flowmoe::cli::Args;
use flowmoe::config::ClusterProfile;
use flowmoe::report::histogram;
use flowmoe::sweep::{fig6_sweep, Sweeper};

fn main() {
    let args = Args::from_env();
    let limit = args.usize_or("limit", usize::MAX);
    let mut sweeper = Sweeper::new().on_progress(|p| {
        if p.done % 64 == 0 || p.done == p.total {
            eprintln!(
                "  [{}/{}] {:.1}s elapsed, ~{:.1}s left",
                p.done, p.total, p.elapsed_s, p.eta_s
            );
        }
    });
    if let Some(t) = args.get("threads").and_then(|t| t.parse().ok()) {
        sweeper = sweeper.with_threads(t);
    }
    eprintln!("sweep engine: {} worker threads", sweeper.threads());

    for (cl, gpus) in [(ClusterProfile::cluster1(16), 16usize), (ClusterProfile::cluster2(8), 8)] {
        let stats = fig6_sweep(&sweeper, &cl, gpus, limit);
        println!(
            "{}",
            histogram(
                &format!(
                    "{} x{gpus}: FlowMoE/ScheMoE speedup over {} valid layers ({} OOM, win rate {:.0}%)",
                    cl.name,
                    stats.speedups.len(),
                    stats.oom,
                    100.0 * stats.wins as f64 / stats.speedups.len().max(1) as f64
                ),
                &stats.speedups,
                12,
                40
            )
        );
        println!("mean speedup: {:.3} (paper: 1.26)", flowmoe::util::mean(&stats.speedups));
    }
}
