"""L2 model tests: shapes, gradients, and the paper's Appendix-H identity
(microbatch loss scaling makes pipelined gradients exactly equal full-batch
gradients)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import TINY, MoEConfig

CFG = TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(jax.random.PRNGKey(1), (CFG.B, CFG.N), 0, CFG.vocab)


def test_param_spec_counts(params):
    assert len(params) == 2 + CFG.L * model.BLOCK_TENSORS
    total = sum(int(np.prod(p.shape)) for p in params)
    # embed + per-block + normf, matching configs.total_params up to norms
    expected = CFG.total_params() + CFG.L * 2 * CFG.M + CFG.M
    assert total == expected


def test_forward_shapes(params, tokens):
    logits = model.forward(params, tokens, CFG)
    assert logits.shape == (CFG.B * CFG.N, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_finite_and_near_uniform_at_init(params, tokens):
    loss = model.loss_fn(params, tokens, CFG)
    assert bool(jnp.isfinite(loss))
    # random init => loss should be within a few nats of log(vocab)
    assert abs(float(loss) - np.log(CFG.vocab)) < 3.0


def test_pallas_and_ref_paths_agree(params, tokens):
    l1 = model.loss_fn(params, tokens, CFG, use_pallas=True)
    l0 = model.loss_fn(params, tokens, CFG, use_pallas=False)
    np.testing.assert_allclose(float(l1), float(l0), rtol=1e-4)


def test_custom_vjp_grads_match_ref_grads(params, tokens):
    """Gradients through the Pallas ops (oracle-VJP wrappers) must equal
    gradients through the pure-ref model."""
    g1 = jax.grad(lambda p: model.loss_fn(p, tokens, CFG, use_pallas=True))(params)
    g0 = jax.grad(lambda p: model.loss_fn(p, tokens, CFG, use_pallas=False))(params)
    for a, b, (name, _) in zip(g1, g0, model.param_spec(CFG)):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-5, err_msg=name)


def test_microbatch_gradient_equivalence(params, tokens):
    """Appendix H: sum_r grad(loss_r / R) == grad(full-batch loss) when the
    microbatch losses are scaled by 1/R.

    Exact equality requires that capacity dropping does not differ between
    the full batch and the microbatches — TINY uses f=E so no token is ever
    dropped (see configs.py); with f small the identity is only approximate
    (a caveat the paper does not state)."""
    R = 2
    full = jax.grad(lambda p: model.loss_fn(p, tokens, CFG))(params)
    acc = [jnp.zeros_like(p) for p in params]
    for r in range(R):
        tb = tokens[r * (CFG.B // R) : (r + 1) * (CFG.B // R)]
        g = jax.grad(lambda p: model.loss_fn(p, tb, CFG) / R)(params)
        acc = [a + x for a, x in zip(acc, g)]
    for a, b, (name, _) in zip(acc, full, model.param_spec(CFG)):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=1e-6, err_msg=name)


def test_train_step_decreases_loss(params, tokens):
    moms = [jnp.zeros_like(p) for p in params]
    p, m = list(params), moms
    losses = []
    for _ in range(5):
        p, m, loss = model.train_step(p, m, tokens, jnp.float32(0.05), CFG)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_grad_step_matches_value_and_grad(params, tokens):
    loss, grads = model.grad_step(params, tokens, CFG)
    l2, g2 = jax.value_and_grad(lambda p: model.loss_fn(p, tokens, CFG))(params)
    np.testing.assert_allclose(float(loss), float(l2), rtol=1e-6)
    for a, b in zip(grads, g2):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-8)


def test_block_fwd_bwd_compose_to_full_model(params, tokens):
    """Composing embed_fwd -> block_fwd* -> head_loss -> block_bwd* ->
    embed_bwd (the exact orchestration rust performs) must reproduce the
    fused grad_step outputs."""
    cfg = MoEConfig(**{**CFG.__dict__, "B": CFG.B})
    embed, normf = params[0], params[-1]
    x = model.embed_fwd(embed, tokens, cfg)
    xs = [x]
    for l in range(cfg.L):
        x = model.block_fwd(model.block_params(params, cfg, l), x, cfg)
        xs.append(x)
    loss, dx, de_head, dnf = model.head_loss_fwd_bwd(embed, normf, xs[-1], tokens, cfg)

    grads_blocks = []
    for l in reversed(range(cfg.L)):
        outs = model.block_bwd(model.block_params(params, cfg, l), xs[l], dx, cfg)
        grads_blocks.insert(0, outs[:9])
        dx = outs[9]
    de = model.embed_bwd(tokens, dx, cfg) + de_head

    loss_f, grads_f = model.grad_step(params, tokens, CFG)
    np.testing.assert_allclose(float(loss), float(loss_f), rtol=1e-5)
    np.testing.assert_allclose(de, grads_f[0], rtol=2e-3, atol=2e-5)
    np.testing.assert_allclose(dnf, grads_f[-1], rtol=2e-3, atol=2e-5)
    for l in range(cfg.L):
        want = grads_f[1 + l * 9 : 1 + (l + 1) * 9]
        for a, b in zip(grads_blocks[l], want):
            np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-5)


def test_rmsnorm_gain_identity():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    from compile.kernels import ref

    y = ref.rmsnorm_ref(x, jnp.ones(8))
    ms = jnp.mean(y * y, axis=-1)
    np.testing.assert_allclose(ms, 1.0, rtol=1e-4)
