"""Expert-parallel decomposition tests.

The rust cluster runtime (rust/src/cluster) orchestrates the EP path as:
at_fwd -> (rust routing) -> dispatch A2A -> exp_fwd on the expert owner ->
combine A2A -> (rust weighted combine) -> residual, and the mirrored
backward. These tests prove, in python, that the decomposition the rust
side performs is numerically identical to the monolithic transformer block,
including the gradient chain (combine-bwd -> gate_bwd/at_bwd, exp_bwd,
dispatch-bwd)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import TINY, MoEConfig
from compile.kernels import ref

CFG = MoEConfig(**{**TINY.__dict__, "B": TINY.B // 2})  # microbatch config
P = 2
EL = CFG.E // P


@pytest.fixture(scope="module")
def setup():
    params = model.init_params(CFG, jax.random.PRNGKey(3))
    bp = model.block_params(params, CFG, 0)
    x = jax.random.normal(jax.random.PRNGKey(4), (CFG.tokens, CFG.M))
    return bp, x


def _ep_forward(bp, x):
    """Replicate the rust EP orchestration in python (single 'worker' doing
    all shards; the sharded A2A exchange is a pure data reshuffle)."""
    atp = bp[:7]
    w1, w2 = bp[7], bp[8]
    C = CFG.capacity()
    h, u, probs, idx, gate = model.at_fwd(atp, x, CFG)
    disp, comb = ref.dispatch_ref(u, idx, gate, CFG.E, C)
    # shard experts across P owners, run exp_fwd per owner, reassemble
    outs = []
    for p in range(P):
        sl = slice(p * EL, (p + 1) * EL)
        outs.append(model.exp_fwd(w1[sl], w2[sl], disp[sl]))
    out = jnp.concatenate(outs, axis=0)
    y = ref.combine_ref(out, comb, gate, u.shape[0])
    return h + y, (h, u, probs, idx, gate, disp, comb, out)


def test_ep_forward_matches_block(setup):
    bp, x = setup
    got, _ = _ep_forward(bp, x)
    want = model.block_fwd(bp, x, CFG)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_ep_backward_matches_block(setup):
    """Full manual backward chain (what rust implements) vs jax.vjp of the
    monolithic block."""
    bp, x = setup
    C = CFG.capacity()
    dy = jax.random.normal(jax.random.PRNGKey(5), (CFG.tokens, CFG.M))

    # ---- forward (saving what rust saves) ----
    _, (h, u, probs, idx, gate, disp, comb, out) = _ep_forward(bp, x)
    w1, w2 = bp[7], bp[8]

    # ---- manual backward ----
    # y = h + combine(out, comb, gate): residual add
    dh_total = dy  # through the residual branch
    # combine-bwd: d_out[e, s] += gate[t,k] * dy[t]; dgate[t,k] = <dy_t, out[e,s]>
    E, Cc, M = out.shape
    d_out = np.zeros((E, Cc + 1, M), np.float32)
    dgate = np.zeros(np.asarray(gate).shape, np.float32)
    outp = np.concatenate([np.asarray(out), np.zeros((E, 1, M), np.float32)], axis=1)
    combn, gaten, dyn = np.asarray(comb), np.asarray(gate), np.asarray(dy)
    T, K = combn.shape[:2]
    for t in range(T):
        for kk in range(K):
            e, s = combn[t, kk]
            d_out[e, s] += gaten[t, kk] * dyn[t]
            dgate[t, kk] = float(dyn[t] @ outp[e, s])
    d_out = jnp.asarray(d_out[:, :Cc])

    # exp_bwd per owner shard
    dw1 = np.zeros_like(np.asarray(w1))
    dw2 = np.zeros_like(np.asarray(w2))
    d_disp = np.zeros_like(np.asarray(disp))
    for p in range(P):
        sl = slice(p * EL, (p + 1) * EL)
        a, b, c = model.exp_bwd(w1[sl], w2[sl], disp[sl], d_out[sl])
        dw1[sl], dw2[sl], d_disp[sl] = np.asarray(a), np.asarray(b), np.asarray(c)

    # dispatch-bwd: du[t] += d_disp[e, s] for each kept (t, k) -> (e, s)
    du = np.zeros((T, M), np.float32)
    for t in range(T):
        for kk in range(K):
            e, s = combn[t, kk]
            if s < Cc:
                du[t] += d_disp[e, s]

    # at_bwd closes the chain (dh through residual, du into u, dgate)
    outs = model.at_bwd(bp[:7], x, dh_total, jnp.asarray(du), jnp.asarray(dgate), CFG)
    datp, dx = outs[:7], outs[7]

    # ---- oracle ----
    _, vjp = jax.vjp(lambda p, xx: model.block_fwd(p, xx, CFG), list(bp), x)
    dbp, dx_want = vjp(dy)

    np.testing.assert_allclose(dx, dx_want, rtol=2e-3, atol=2e-5)
    for i in range(7):
        np.testing.assert_allclose(datp[i], dbp[i], rtol=2e-3, atol=2e-5, err_msg=f"atp[{i}]")
    np.testing.assert_allclose(dw1, dbp[7], rtol=2e-3, atol=2e-5)
    np.testing.assert_allclose(dw2, dbp[8], rtol=2e-3, atol=2e-5)


def test_gate_bwd_matches_vjp(setup):
    bp, x = setup
    _, u, probs, idx, gate = model.at_fwd(bp[:7], x, CFG)
    T = u.shape[0]
    sel = jax.nn.one_hot(idx, CFG.E)
    dgate = jax.random.normal(jax.random.PRNGKey(6), gate.shape)
    dprobs = model.gate_bwd(probs, sel, dgate)

    def f(p):
        g = jnp.einsum("te,tke->tk", p, sel)
        return g / jnp.maximum(jnp.sum(g, axis=-1, keepdims=True), 1e-9)

    _, vjp = jax.vjp(f, probs)
    np.testing.assert_allclose(dprobs, vjp(dgate)[0], rtol=1e-4, atol=1e-6)


def test_dispatch_is_linear(setup):
    """dispatch is linear in x given fixed routing — the property rust's
    dispatch-bwd (transpose scatter) relies on."""
    bp, x = setup
    u = jax.random.normal(jax.random.PRNGKey(7), (CFG.tokens, CFG.M))
    v = jax.random.normal(jax.random.PRNGKey(8), (CFG.tokens, CFG.M))
    _, idx, gate = ref.gating_ref(u, bp[6], CFG.k)
    C = CFG.capacity()
    d1, _ = ref.dispatch_ref(u, idx, gate, CFG.E, C)
    d2, _ = ref.dispatch_ref(v, idx, gate, CFG.E, C)
    d12, _ = ref.dispatch_ref(u + 2.0 * v, idx, gate, CFG.E, C)
    np.testing.assert_allclose(d12, d1 + 2.0 * d2, rtol=1e-4, atol=1e-5)
