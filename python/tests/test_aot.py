"""AOT pipeline structural tests: manifest consistency and HLO sanity."""

import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.txt")),
    reason="artifacts not built (run `make artifacts`)",
)


def parse_manifest():
    arts = {}
    cur = None
    for line in open(os.path.join(ART, "manifest.txt")):
        line = line.rstrip("\n")
        if line.startswith("artifact "):
            parts = line.split()
            name = parts[1]
            kv = dict(p.split("=", 1) for p in parts[2:] if "=" in p)
            cur = {"file": kv["file"], "config": kv["config"], "inputs": [], "outputs": []}
            arts[name] = cur
        elif line.strip().startswith("input "):
            _, nm, shape, dt = line.split()
            cur["inputs"].append((nm, shape, dt))
        elif line.strip().startswith("output "):
            _, nm, shape, dt = line.split()
            cur["outputs"].append((nm, shape, dt))
    return arts


def test_manifest_files_exist():
    arts = parse_manifest()
    assert len(arts) >= 16
    for name, a in arts.items():
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), f"{name}: missing {a['file']}"
        head = open(path).read(4096)
        assert "HloModule" in head, f"{name}: not HLO text"
        assert "ENTRY" in open(path).read(), f"{name}: no ENTRY computation"


def test_manifest_io_counts():
    from compile import model
    from compile.configs import PRESETS

    arts = parse_manifest()
    for cfgname in {a["config"] for a in arts.values()}:
        cfg = PRESETS[cfgname]
        n_p = len(model.param_spec(cfg))
        ts = arts[f"train_step_{cfgname}"]
        assert len(ts["inputs"]) == 2 * n_p + 2
        assert len(ts["outputs"]) == 2 * n_p + 1
        gs = arts[f"grad_step_{cfgname}"]
        assert len(gs["inputs"]) == n_p + 1
        assert len(gs["outputs"]) == n_p + 1
        bf = arts[f"block_fwd_{cfgname}"]
        assert len(bf["inputs"]) == 10 and len(bf["outputs"]) == 1
        bb = arts[f"block_bwd_{cfgname}"]
        assert len(bb["inputs"]) == 11 and len(bb["outputs"]) == 10


def test_manifest_shapes_match_model_spec():
    from compile import model
    from compile.configs import PRESETS

    arts = parse_manifest()
    for cfgname in {a["config"] for a in arts.values()}:
        cfg = PRESETS[cfgname]
        spec = model.param_spec(cfg)
        ts = arts[f"train_step_{cfgname}"]
        for (mn, ms, dt), (sn, ss) in zip(ts["inputs"], spec):
            assert mn == f"param.{sn}"
            want = "x".join(str(d) for d in ss)
            assert ms == want, f"{mn}: {ms} != {want}"
            assert dt == "f32"


def test_hlo_has_no_serialized_proto_markers():
    """Interchange must be HLO text (xla_extension 0.5.1 rejects jax>=0.5
    serialized protos)."""
    arts = parse_manifest()
    for a in arts.values():
        with open(os.path.join(ART, a["file"]), "rb") as f:
            head = f.read(64)
        assert head.lstrip()[:9] == b"HloModule"
