"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/k; assert_allclose against ref.py is the
core correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.expert_ffn import expert_ffn, vmem_report, _pick_token_tile
from compile.kernels.gating import gating_topk

SETTINGS = dict(max_examples=20, deadline=None)


def rng(*shape, seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# expert FFN
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    E=st.integers(1, 6),
    C=st.integers(1, 24),
    M=st.sampled_from([8, 16, 33, 64]),
    H=st.sampled_from([8, 24, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_expert_ffn_matches_ref(E, C, M, H, seed):
    x = rng(E, C, M, seed=seed)
    w1 = rng(E, M, H, seed=seed + 1, scale=0.2)
    w2 = rng(E, H, M, seed=seed + 2, scale=0.2)
    got = expert_ffn(x, w1, w2)
    want = ref.expert_ffn_ref(x, w1, w2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("tile", [1, 2, 4, 8])
def test_expert_ffn_token_tiles_agree(tile):
    x, w1, w2 = rng(2, 8, 16), rng(2, 16, 32, scale=0.2), rng(2, 32, 16, scale=0.2)
    got = expert_ffn(x, w1, w2, token_tile=tile)
    np.testing.assert_allclose(got, ref.expert_ffn_ref(x, w1, w2), rtol=1e-4, atol=1e-5)


def test_expert_ffn_zero_input_is_zero():
    x = jnp.zeros((2, 4, 8))
    out = expert_ffn(x, rng(2, 8, 16), rng(2, 16, 8))
    np.testing.assert_allclose(out, 0.0, atol=1e-7)


def test_pick_token_tile_respects_budget():
    for (C, M, H) in [(64, 512, 1024), (256, 1024, 4096), (128, 8192, 8192)]:
        r = vmem_report(C, M, H)
        assert r["vmem_bytes"] <= 12 * 1024 * 1024 or r["token_tile"] == 1
        assert 0.0 < r["mxu_utilization_est"] <= 1.0


def test_pick_token_tile_monotone_in_capacity():
    assert _pick_token_tile(256, 128, 128) >= _pick_token_tile(4, 128, 128)


# ---------------------------------------------------------------------------
# gating
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    T=st.sampled_from([1, 4, 16, 30]),
    M=st.sampled_from([8, 32]),
    E=st.sampled_from([2, 4, 8, 16]),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_gating_matches_ref(T, M, E, k, seed):
    if k > E:
        k = E
    x = rng(T, M, seed=seed)
    wg = rng(M, E, seed=seed + 1)
    p1, i1, g1 = gating_topk(x, wg, k)
    p0, i0, g0 = ref.gating_ref(x, wg, k)
    np.testing.assert_allclose(p1, p0, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(i1, i0)
    np.testing.assert_allclose(g1, g0, rtol=1e-5, atol=1e-6)


def test_gating_probs_sum_to_one():
    p, _, _ = gating_topk(rng(8, 16), rng(16, 4, seed=1), 2)
    np.testing.assert_allclose(jnp.sum(p, axis=-1), 1.0, rtol=1e-5)


def test_gating_topk_gates_sum_to_one():
    _, _, g = gating_topk(rng(8, 16), rng(16, 4, seed=1), 3)
    np.testing.assert_allclose(jnp.sum(g, axis=-1), 1.0, rtol=1e-5)


def test_gating_indices_in_range_and_distinct():
    _, idx, _ = gating_topk(rng(32, 16), rng(16, 8, seed=2), 4)
    idx = np.asarray(idx)
    assert idx.min() >= 0 and idx.max() < 8
    for row in idx:
        assert len(set(row.tolist())) == 4


def test_gating_token_tiling_agrees():
    x, wg = rng(16, 8), rng(8, 4, seed=3)
    p1, i1, g1 = gating_topk(x, wg, 2, token_tile=4)
    p0, i0, g0 = gating_topk(x, wg, 2)
    np.testing.assert_allclose(p1, p0, rtol=1e-6)
    np.testing.assert_array_equal(i1, i0)
    np.testing.assert_allclose(g1, g0, rtol=1e-6)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    B=st.integers(1, 3),
    NH=st.integers(1, 4),
    N=st.sampled_from([8, 16, 32]),
    D=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(B, NH, N, D, causal, seed):
    q = rng(B, NH, N, D, seed=seed)
    k = rng(B, NH, N, D, seed=seed + 1)
    v = rng(B, NH, N, D, seed=seed + 2)
    got = attention(q, k, v, causal=causal)
    want = (ref.attention_causal_ref if causal else ref.attention_ref)(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("qb,kb", [(4, 4), (4, 8), (8, 4), (16, 16)])
def test_attention_tilings_agree(qb, kb):
    q, k, v = rng(2, 2, 16, 8), rng(2, 2, 16, 8, seed=1), rng(2, 2, 16, 8, seed=2)
    got = attention(q, k, v, causal=True, q_block=qb, kv_block=kb)
    want = ref.attention_causal_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_softmax_rows_bounded():
    # outputs are convex combinations of V rows => within [min(V), max(V)]
    q, k = rng(1, 1, 8, 4), rng(1, 1, 8, 4, seed=1)
    v = jnp.ones((1, 1, 8, 4))
    out = attention(q, k, v)
    np.testing.assert_allclose(out, 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# dispatch / combine (routing oracle invariants used by rust EP path)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    T=st.sampled_from([4, 16, 64]),
    E=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
    f=st.sampled_from([1.0, 1.2, 2.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dispatch_combine_roundtrip_identity_experts(T, E, k, f, seed):
    """With identity experts (out == in), combine(dispatch(x)) reproduces a
    convex combination of x for every non-dropped token."""
    M = 8
    C = max(int(f * k * T / E), 1)
    x = rng(T, M, seed=seed)
    wg = rng(M, E, seed=seed + 1)
    _, idx, gate = ref.gating_ref(x, wg, k)
    disp, comb = ref.dispatch_ref(x, idx, gate, E, C)
    y = ref.combine_ref(disp, comb, gate, T)
    comb = np.asarray(comb)
    gate = np.asarray(gate)
    kept_w = np.where(comb[..., 1] < C, gate, 0.0).sum(-1)
    np.testing.assert_allclose(y, np.asarray(x) * kept_w[:, None], rtol=1e-4, atol=1e-5)


def test_dispatch_capacity_never_exceeded():
    T, E, k, C, M = 64, 2, 2, 4, 8
    x, wg = rng(T, M), rng(M, E, seed=1)
    _, idx, gate = ref.gating_ref(x, wg, k)
    disp, comb = ref.dispatch_ref(x, idx, gate, E, C)
    assert disp.shape == (E, C, M)
    slots = np.asarray(comb)[..., 1]
    assert slots.max() <= C  # C == drop bucket
