"""AOT pipeline: lower the L2 model to HLO *text* artifacts for rust/PJRT.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``: jax
>= 0.5 emits protos with 64-bit instruction ids which the rust ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

For every exported entry point we also emit a line-based manifest
(``artifacts/manifest.txt``) describing the positional input/output buffers
(name, shape, dtype, role) that the rust runtime parses to allocate and
wire buffers — no shape knowledge is duplicated in rust.

Usage: ``python -m compile.aot --out-dir ../artifacts [--configs tiny,e2e]``
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import PRESETS, MoEConfig

jax.config.update("jax_platform_name", "cpu")

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt_shape(shape):
    return "x".join(str(d) for d in shape) if shape else "scalar"


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest_lines = []
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name: str, fn, in_specs, in_names, out_names, config: str, extra=""):
        """Lower ``fn`` at ``in_specs`` and record manifest entries.

        in_specs is a flat list of ShapeDtypeStructs; fn takes them as
        positional args and returns a flat tuple.
        """
        path = f"{name}.hlo.txt"
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        # output shapes from the lowered signature
        out_avals = lowered.out_info
        flat_out = jax.tree_util.tree_leaves(out_avals)
        assert len(flat_out) == len(out_names), (
            f"{name}: {len(flat_out)} outputs vs {len(out_names)} names"
        )
        lines = [f"artifact {name} file={path} config={config} {extra}".rstrip()]
        for spec, nm in zip(in_specs, in_names):
            dt = "i32" if spec.dtype == jnp.int32 else "f32"
            lines.append(f"  input {nm} {_fmt_shape(spec.shape)} {dt}")
        for out, nm in zip(flat_out, out_names):
            dt = "i32" if out.dtype == jnp.int32 else "f32"
            lines.append(f"  output {nm} {_fmt_shape(out.shape)} {dt}")
        self.manifest_lines.extend(lines)
        print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)", flush=True)

    def finish(self, regenerated_configs):
        """Write the manifest, keeping entries of configs not regenerated
        this run (so partial re-exports don't clobber other configs)."""
        path = os.path.join(self.out_dir, "manifest.txt")
        kept = []
        if os.path.exists(path):
            keep = False
            for line in open(path):
                line = line.rstrip("\n")
                if line.startswith("artifact "):
                    keep = f"config={line.split('config=')[1].split()[0]}".split("=")[1] not in regenerated_configs
                if keep and line:
                    kept.append(line)
        with open(path, "w") as f:
            f.write("\n".join(kept + self.manifest_lines) + "\n")
        print(f"wrote manifest.txt ({len(kept) + len(self.manifest_lines)} lines)")


def param_specs(cfg: MoEConfig):
    return [_spec(s) for _, s in model.param_spec(cfg)]


def param_names(cfg: MoEConfig, prefix="param"):
    return [f"{prefix}.{n}" for n, _ in model.param_spec(cfg)]


def block_specs(cfg: MoEConfig):
    return [_spec(s) for _, s in model.param_spec(cfg)[1 : 1 + model.BLOCK_TENSORS]]


def block_names(cfg: MoEConfig, prefix):
    names = [n.split(".", 1)[1] for n, _ in model.param_spec(cfg)[1 : 1 + model.BLOCK_TENSORS]]
    return [f"{prefix}.{n}" for n in names]


def export_config(ex: Exporter, cfg: MoEConfig, ep_workers: int = 0, micro_r: int = 2,
                  use_pallas: bool = True):
    # use_pallas=False lowers the pure-jnp oracle path instead of the
    # interpret-mode Pallas kernels. Semantics are identical (the test
    # suite asserts kernel == oracle everywhere); interpret-mode emulation
    # is ~11x slower on the CPU PJRT backend (EXPERIMENTS.md §Perf), so
    # the big e2e training config lowers the oracle path while the tiny
    # config keeps the full Pallas path as the TPU-shaped artifact.
    c = cfg.name
    n_p = len(model.param_spec(cfg))
    psp, pnm = param_specs(cfg), param_names(cfg)
    tok = _spec((cfg.B, cfg.N), I32)

    print(f"[{c}] fused train_step / grad_step", flush=True)

    def ts(*args):
        params = list(args[:n_p])
        moms = list(args[n_p : 2 * n_p])
        tokens, lr = args[2 * n_p], args[2 * n_p + 1]
        np_, nm_, loss = model.train_step(params, moms, tokens, lr, cfg, use_pallas=use_pallas)
        return tuple(np_) + tuple(nm_) + (loss,)

    ex.export(
        f"train_step_{c}", ts,
        psp + psp + [tok, _spec(())],
        pnm + param_names(cfg, "mom") + ["tokens", "lr"],
        param_names(cfg, "new_param") + param_names(cfg, "new_mom") + ["loss"],
        c,
    )

    def gs(*args):
        params = list(args[:n_p])
        tokens = args[n_p]
        loss, grads = model.grad_step(params, tokens, cfg, use_pallas=use_pallas)
        return (loss,) + tuple(grads)

    ex.export(
        f"grad_step_{c}", gs,
        psp + [tok],
        pnm + ["tokens"],
        ["loss"] + param_names(cfg, "grad"),
        c,
    )

    # --- per-block pieces at microbatch granularity (pipelined trainer) ---
    bm = cfg.B // micro_r
    assert cfg.B % micro_r == 0
    tm = bm * cfg.N
    mcfg = MoEConfig(**{**cfg.__dict__, "name": c, "B": bm})
    bsp, x_sp = block_specs(cfg), _spec((tm, cfg.M))
    tok_m = _spec((bm, cfg.N), I32)
    print(f"[{c}] per-block microbatch pieces (R={micro_r}, Tm={tm})", flush=True)

    def bf(*args):
        return (model.block_fwd(list(args[:9]), args[9], mcfg, use_pallas=use_pallas),)

    ex.export(
        f"block_fwd_{c}", bf, bsp + [x_sp],
        block_names(cfg, "bp") + ["x"], ["y"], c,
        extra=f"micro_batch={bm}",
    )

    def bb(*args):
        return tuple(model.block_bwd(list(args[:9]), args[9], args[10], mcfg, use_pallas=use_pallas))

    ex.export(
        f"block_bwd_{c}", bb, bsp + [x_sp, x_sp],
        block_names(cfg, "bp") + ["x", "dy"],
        block_names(cfg, "grad") + ["dx"], c,
        extra=f"micro_batch={bm}",
    )

    emb_sp = _spec((cfg.vocab, cfg.M))
    nf_sp = _spec((cfg.M,))

    ex.export(
        f"embed_fwd_{c}",
        lambda e, t: (model.embed_fwd(e, t, mcfg),),
        [emb_sp, tok_m], ["param.embed", "tokens"], ["x"], c,
        extra=f"micro_batch={bm}",
    )

    def hl(e, nf, xf, t):
        return model.head_loss_fwd_bwd(e, nf, xf, t, mcfg)

    ex.export(
        f"head_loss_{c}", hl, [emb_sp, nf_sp, x_sp, tok_m],
        ["param.embed", "param.normf", "xf", "tokens"],
        ["loss", "dxf", "grad.embed_head", "grad.normf"], c,
        extra=f"micro_batch={bm}",
    )

    ex.export(
        f"embed_bwd_{c}",
        lambda t, dx: (model.embed_bwd(t, dx, mcfg),),
        [tok_m, x_sp],
        ["tokens", "dx"], ["grad.embed"], c,
        extra=f"micro_batch={bm}",
    )

    # --- expert-parallel layer pieces (real-A2A path), fixed worker count ---
    if ep_workers:
        P = ep_workers
        assert cfg.E % P == 0
        el = cfg.E // P
        C = cfg.capacity()  # per-source-worker per-expert capacity
        cw = C * P  # tokens an expert owner may receive in total
        atp_sp = bsp[:7]
        atp_nm = block_names(cfg, "atp")[:7]
        print(f"[{c}] EP pieces (P={P}, Elocal={el}, Cw={cw})", flush=True)

        def af(*args):
            h, u, probs, idx, gate = model.at_fwd(list(args[:7]), args[7], mcfg)
            return h, u, probs, idx, gate

        ex.export(
            f"at_fwd_{c}", af, atp_sp + [x_sp],
            atp_nm + ["x"], ["h", "u", "probs", "idx", "gate"], c,
            extra=f"micro_batch={bm} ep_workers={P}",
        )

        def ab(*args):
            return tuple(model.at_bwd(list(args[:7]), args[7], args[8], args[9], args[10], mcfg))

        ex.export(
            f"at_bwd_{c}", ab,
            atp_sp + [x_sp, x_sp, x_sp, _spec((tm, cfg.k))],
            atp_nm + ["x", "dh", "du", "dgate"],
            [n.replace("atp.", "grad.") for n in atp_nm] + ["dx"], c,
            extra=f"micro_batch={bm} ep_workers={P}",
        )

        w1_sp = _spec((el, cfg.M, cfg.H))
        w2_sp = _spec((el, cfg.H, cfg.M))
        xd_sp = _spec((el, cw, cfg.M))

        ex.export(
            f"exp_fwd_{c}",
            lambda w1, w2, xd: (model.exp_fwd(w1, w2, xd),),
            [w1_sp, w2_sp, xd_sp], ["w1", "w2", "xd"], ["yd"], c,
            extra=f"ep_workers={P}",
        )

        def eb(w1, w2, xd, dyd):
            return tuple(model.exp_bwd(w1, w2, xd, dyd))

        ex.export(
            f"exp_bwd_{c}", eb, [w1_sp, w2_sp, xd_sp, xd_sp],
            ["w1", "w2", "xd", "dyd"], ["dw1", "dw2", "dxd"], c,
            extra=f"ep_workers={P}",
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,e2e")
    args = ap.parse_args()

    ex = Exporter(args.out_dir)
    names = args.configs.split(",")
    for name in names:
        cfg = PRESETS[name]
        # EP pieces only for the tiny config (2-worker integration tests).
        export_config(ex, cfg, ep_workers=2 if name == "tiny" else 0,
                      use_pallas=(name == "tiny"))
    ex.finish(set(names))


if __name__ == "__main__":
    main()
