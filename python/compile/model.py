"""L2: the MoE transformer in JAX, calling the L1 Pallas kernels.

Build-time only — ``aot.py`` lowers the functions defined here to HLO text;
python never runs on the training hot path. The model is a pre-norm
decoder-only transformer whose feed-forward layers are MoE layers (paper
Fig. 1a): RMSNorm -> MHA -> residual -> RMSNorm -> top-k gate -> dispatch
-> expert FFN -> combine -> residual, with a tied-embedding LM head.

Parameters use a canonical flat order (``param_spec``) so the rust runtime
can address buffers positionally:

    embed (V, M)
    for each block l: n1 (M,), wq, wk, wv, wo (M, M), n2 (M,),
                      wg (M, E), w1 (E, M, H), w2 (E, H, M)
    normf (M,)

The Pallas kernels are wrapped in ``jax.custom_vjp`` — forward runs the
kernel, backward differentiates the pure-jnp oracle (Pallas interpret mode
has no transpose rule). Numerics of fwd and bwd are therefore both
oracle-exact.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import MoEConfig
from .kernels import ref
from .kernels.attention import attention as attention_kernel
from .kernels.expert_ffn import expert_ffn as expert_ffn_kernel
from .kernels.gating import gating_topk as gating_kernel

# ---------------------------------------------------------------------------
# Pallas kernels with oracle-gradient custom VJPs
# ---------------------------------------------------------------------------


@jax.custom_vjp
def expert_ffn_op(x, w1, w2):
    return expert_ffn_kernel(x, w1, w2)


def _effn_fwd(x, w1, w2):
    return expert_ffn_kernel(x, w1, w2), (x, w1, w2)


def _effn_bwd(res, g):
    return jax.vjp(ref.expert_ffn_ref, *res)[1](g)


expert_ffn_op.defvjp(_effn_fwd, _effn_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def gating_op(x, wg, k):
    return gating_kernel(x, wg, k)


def _gate_fwd(x, wg, k):
    return gating_kernel(x, wg, k), (x, wg)


def _gate_bwd(k, res, g):
    x, wg = res
    dprobs, _didx, dgate = g

    def f(x_, wg_):
        probs, idx, gate = ref.gating_ref(x_, wg_, k)
        return probs, gate

    _, vjp = jax.vjp(f, x, wg)
    return vjp((dprobs, dgate))


gating_op.defvjp(_gate_fwd, _gate_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention_op(q, k, v, causal):
    return attention_kernel(q, k, v, causal=causal)


def _attn_fwd(q, k, v, causal):
    return attention_kernel(q, k, v, causal=causal), (q, k, v)


def _attn_bwd(causal, res, g):
    fn = ref.attention_causal_ref if causal else ref.attention_ref
    return jax.vjp(fn, *res)[1](g)


attention_op.defvjp(_attn_fwd, _attn_bwd)


# ---------------------------------------------------------------------------
# Parameter handling
# ---------------------------------------------------------------------------

BLOCK_TENSORS = 9  # n1, wq, wk, wv, wo, n2, wg, w1, w2


def param_spec(cfg: MoEConfig):
    """Canonical flat parameter order: list of (name, shape) tuples."""
    spec = [("embed", (cfg.vocab, cfg.M))]
    for l in range(cfg.L):
        spec += [
            (f"block{l}.n1", (cfg.M,)),
            (f"block{l}.wq", (cfg.M, cfg.M)),
            (f"block{l}.wk", (cfg.M, cfg.M)),
            (f"block{l}.wv", (cfg.M, cfg.M)),
            (f"block{l}.wo", (cfg.M, cfg.M)),
            (f"block{l}.n2", (cfg.M,)),
            (f"block{l}.wg", (cfg.M, cfg.E)),
            (f"block{l}.w1", (cfg.E, cfg.M, cfg.H)),
            (f"block{l}.w2", (cfg.E, cfg.H, cfg.M)),
        ]
    spec.append(("normf", (cfg.M,)))
    return spec


def init_params(cfg: MoEConfig, key):
    """Scaled-normal init; norm gains start at 1."""
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".n1", ".n2")) or name == "normf":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            params.append(jax.random.normal(sub, shape, jnp.float32) * (fan_in ** -0.5))
    return params


def block_params(params, cfg: MoEConfig, l: int):
    base = 1 + l * BLOCK_TENSORS
    return params[base : base + BLOCK_TENSORS]


# ---------------------------------------------------------------------------
# Model pieces
# ---------------------------------------------------------------------------


def mha(p, x, cfg: MoEConfig, causal=True, use_pallas=True):
    """Multi-head attention over (T, M) flat tokens, T = B*N."""
    n1, wq, wk, wv, wo = p[0], p[1], p[2], p[3], p[4]
    T = x.shape[0]
    B = T // cfg.N
    xn = ref.rmsnorm_ref(x, n1)
    q = (xn @ wq).reshape(B, cfg.N, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = (xn @ wk).reshape(B, cfg.N, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = (xn @ wv).reshape(B, cfg.N, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    if use_pallas:
        o = attention_op(q, k, v, causal)
    else:
        o = (ref.attention_causal_ref if causal else ref.attention_ref)(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(T, cfg.M)
    return x + o @ wo


def at_task(p, x, cfg: MoEConfig, use_pallas=True):
    """The paper's AT task: MHA + gating for one (micro)batch.

    Returns (h, u, logits-as-probs tuple) where h is the residual stream
    after attention and u the normed MoE input.
    """
    h = mha(p, x, cfg, use_pallas=use_pallas)
    u = ref.rmsnorm_ref(h, p[5])
    if use_pallas:
        probs, idx, gate = gating_op(u, p[6], cfg.k)
    else:
        probs, idx, gate = ref.gating_ref(u, p[6], cfg.k)
    return h, u, probs, idx, gate


def moe_ffn(p, h, u, idx, gate, cfg: MoEConfig, C: int, use_pallas=True):
    """Dispatch -> expert FFN -> combine -> residual (single-worker dense)."""
    w1, w2 = p[7], p[8]
    disp, comb = ref.dispatch_ref(u, idx, gate, cfg.E, C)
    if use_pallas:
        out = expert_ffn_op(disp, w1, w2)
    else:
        out = ref.expert_ffn_ref(disp, w1, w2)
    y = ref.combine_ref(out, comb, gate, u.shape[0])
    return h + y


def transformer_block(p, x, cfg: MoEConfig, use_pallas=True):
    C = cfg.capacity()
    h, u, _probs, idx, gate = at_task(p, x, cfg, use_pallas=use_pallas)
    return moe_ffn(p, h, u, idx, gate, cfg, C, use_pallas=use_pallas)


def forward(params, tokens, cfg: MoEConfig, use_pallas=True):
    """Full model: tokens (B, N) int32 -> logits (B*N, V)."""
    embed = params[0]
    x = embed[tokens.reshape(-1)] * (cfg.M ** 0.5)
    for l in range(cfg.L):
        x = transformer_block(block_params(params, cfg, l), x, cfg, use_pallas=use_pallas)
    xf = ref.rmsnorm_ref(x, params[-1])
    return xf @ embed.T


def loss_fn(params, tokens, cfg: MoEConfig, use_pallas=True):
    """Next-token cross-entropy, mean over B*(N-1) positions."""
    logits = forward(params, tokens, cfg, use_pallas=use_pallas)
    B, N = tokens.shape
    logits = logits.reshape(B, N, -1)[:, :-1]
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Exported entry points (lowered by aot.py)
# ---------------------------------------------------------------------------


def train_step(params, moms, tokens, lr, cfg: MoEConfig, use_pallas=True, momentum=0.9):
    """Fused single-process SGD+momentum step.

    Returns (new_params, new_moms, loss) with params/moms flat lists in
    canonical order.
    """
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, tokens, cfg, use_pallas))(params)
    new_moms = [momentum * m + g for m, g in zip(moms, grads)]
    new_params = [p - lr * m for p, m in zip(params, new_moms)]
    return new_params, new_moms, loss


def grad_step(params, tokens, cfg: MoEConfig, use_pallas=True):
    """Per-worker gradient computation (loss, grads) for the distributed
    data-parallel trainer: rust all-reduces the grads (chunked by S_p via
    the comm pool) and applies the update host-side."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, tokens, cfg, use_pallas))(params)
    return loss, grads


def block_fwd(bp, x, cfg: MoEConfig, use_pallas=True):
    """Forward of one transformer block over flat (T, M) activations."""
    return transformer_block(bp, x, cfg, use_pallas=use_pallas)


def block_bwd(bp, x, dy, cfg: MoEConfig, use_pallas=True):
    """Recompute-based VJP of one block: (grads_block[9], dx).

    Rematerializes the forward inside the backward artifact so no residual
    plumbing crosses the rust/HLO boundary (DESIGN.md §5).
    """
    _, vjp = jax.vjp(lambda p, x_: block_fwd(p, x_, cfg, use_pallas), list(bp), x)
    dparams, dx = vjp(dy)
    return list(dparams) + [dx]


def embed_fwd(embed, tokens, cfg: MoEConfig):
    return embed[tokens.reshape(-1)] * (cfg.M ** 0.5)


def head_loss_fwd_bwd(embed, normf, xf, tokens, cfg: MoEConfig):
    """Final norm + tied LM head + cross-entropy, fused fwd+bwd.

    Returns (loss, dxf, dembed_head, dnormf).
    """

    def f(e, nf, x_):
        xn = ref.rmsnorm_ref(x_, nf)
        logits = (xn @ e.T).reshape(tokens.shape[0], tokens.shape[1], -1)[:, :-1]
        targets = tokens[:, 1:]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    loss, vjp = jax.vjp(f, embed, normf, xf)
    de, dn, dx = vjp(jnp.float32(1.0))
    return loss, dx, de, dn


def embed_bwd(tokens, dx, cfg: MoEConfig):
    """Gradient of the input embedding lookup (scatter-add).

    Takes no ``embed`` argument: the gradient depends only on its *shape*
    (XLA prunes value-unused parameters at compile time, which would make
    the artifact's runtime arity differ from its manifest arity)."""
    z = jnp.zeros((cfg.vocab, cfg.M), jnp.float32)
    return z.at[tokens.reshape(-1)].add(dx * (cfg.M ** 0.5))


# --- Expert-parallel layer pieces (real-A2A path in rust/src/cluster) ---


def at_fwd(atp, x, cfg: MoEConfig, use_pallas=True):
    """AT piece for the EP path: atp = [n1,wq,wk,wv,wo,n2,wg].

    Returns (h, u, probs, gate_topk, idx) — rust performs routing/dispatch
    from idx/gate and the A2A exchange.
    """
    p = list(atp) + [None, None]
    h, u, probs, idx, gate = at_task(p, x, cfg, use_pallas=use_pallas)
    return h, u, probs, idx, gate


def at_bwd(atp, x, dh, du, dgate, cfg: MoEConfig, use_pallas=True):
    """Recompute-based VJP of the AT piece for the EP path.

    Differentiates (atp, x) -> (h, u, gate); idx is recomputed identically
    inside (routing is deterministic), probs only feed gate. Cotangents:
    dh from the downstream residual add, du from dispatch-bwd, dgate from
    combine-bwd. Returns grads for [n1,wq,wk,wv,wo,n2,wg] followed by dx.
    """

    def f(p, x_):
        h, u, _probs, _idx, gate = at_fwd(p, x_, cfg, use_pallas=use_pallas)
        return h, u, gate

    _, vjp = jax.vjp(f, list(atp), x)
    dparams, dx = vjp((dh, du, dgate))
    return list(dparams) + [dx]


def exp_fwd(w1, w2, xd, use_pallas=True):
    """Expert piece for the EP path: xd (Elocal, Cw, M) tokens received via
    A2A; w1 (Elocal, M, H), w2 (Elocal, H, M)."""
    if use_pallas:
        return expert_ffn_op(xd, w1, w2)
    return ref.expert_ffn_ref(xd, w1, w2)


def exp_bwd(w1, w2, xd, dyd, use_pallas=True):
    """VJP of exp_fwd (recompute): returns (dw1, dw2, dxd)."""
    _, vjp = jax.vjp(lambda a, b, c: exp_fwd(a, b, c, use_pallas), w1, w2, xd)
    return vjp(dyd)


def gate_bwd(logits_probs, sel_onehot, dgate):
    """VJP of the renormalized top-k gate weights w.r.t. full probs.

    Args:
        logits_probs: (T, E) softmax probabilities (as produced by at_fwd).
        sel_onehot:   (T, k, E) one-hot selection (fixed, non-diff).
        dgate:        (T, k) cotangent of the renormalized gate weights.
    Returns:
        dprobs (T, E).
    """

    def f(probs):
        g = jnp.einsum("te,tke->tk", probs, sel_onehot)
        return g / jnp.maximum(jnp.sum(g, axis=-1, keepdims=True), 1e-9)

    _, vjp = jax.vjp(f, logits_probs)
    return vjp(dgate)[0]
