"""Model configurations for the FlowMoE reproduction.

Mirrors Table 2 of the paper plus the configs used by the AOT pipeline:
``tiny`` for fast tests and ``e2e`` for the ~100M-parameter end-to-end
training example driven from rust.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    """A transformer-with-MoE-layers configuration (paper Table 2 notation).

    Attributes:
        name: human-readable config name.
        L: number of transformer blocks.
        B: mini-batch size per worker (samples per iteration).
        N: tokens per sample.
        M: token embedding size.
        H: expert feed-forward hidden size.
        E: total number of experts per MoE layer (across the cluster).
        k: top-k experts per token.
        f: capacity factor.
        n_heads: attention heads (M must be divisible).
        vocab: vocabulary size for the LM head (0 = no embedding/head,
            pure block stack operating on continuous inputs).
    """

    name: str
    L: int
    B: int
    N: int
    M: int
    H: int
    E: int
    k: int
    f: float = 1.0
    n_heads: int = 8
    vocab: int = 0

    @property
    def tokens(self) -> int:
        """Tokens per worker per iteration."""
        return self.B * self.N

    def capacity(self, n_workers: int = 1) -> int:
        """Max tokens routed to one expert: C = f * k * B * N / E.

        The paper computes C from the per-worker token count; we keep the
        same convention (B is per-GPU batch).
        """
        c = int(self.f * self.k * self.B * self.N / self.E)
        return max(c, 1)

    @property
    def head_dim(self) -> int:
        assert self.M % self.n_heads == 0
        return self.M // self.n_heads

    def mha_gating_params(self) -> int:
        """Parameter count of the replicated (data-parallel) part per block:
        Q,K,V,O projections + gate, matching the paper's 4M^2 + M*E."""
        return 4 * self.M * self.M + self.M * self.E

    def expert_params(self) -> int:
        """Parameter count of all experts of one block: E * 2 * M * H."""
        return self.E * 2 * self.M * self.H

    def total_params(self) -> int:
        p = self.L * (self.mha_gating_params() + self.expert_params())
        if self.vocab:
            p += self.vocab * self.M  # tied embedding / LM head
        return p


# --- Paper Table 2 models (E/P column = experts per worker; E here is the
# cluster-wide expert count for the 16-GPU setting used in most tables). ---

GPT2_TINY_MOE = MoEConfig("GPT2-Tiny-MoE", L=12, B=4, N=256, M=256, H=512, E=16, k=2, n_heads=4, vocab=50257)
BERT_LARGE_MOE = MoEConfig("BERT-Large-MoE", L=24, B=4, N=512, M=512, H=1024, E=32, k=1, n_heads=8, vocab=30522)
LLAMA2_MOE = MoEConfig("LLaMA2-MoE", L=32, B=4, N=512, M=1024, H=4096, E=16, k=1, n_heads=16, vocab=32000)
LLAMA2_MOE_L = MoEConfig("LLaMA2-MoE-L", L=64, B=4, N=512, M=1024, H=4096, E=16, k=1, n_heads=16, vocab=32000)
DEEPSEEK_V2_S = MoEConfig("DeepSeek-V2-S", L=4, B=4, N=256, M=5120, H=1536, E=32, k=8, n_heads=16, vocab=32000)
DEEPSEEK_V2_M = MoEConfig("DeepSeek-V2-M", L=7, B=4, N=256, M=5120, H=1536, E=32, k=1, n_heads=16, vocab=32000)

# --- Configs used by the AOT pipeline. ---

# Tiny: fast pytest / rust-integration-test config. f=E makes the capacity
# generous enough that no token is ever dropped, so microbatch-pipelined
# execution is *exactly* equivalent to full-batch execution (the paper's
# Appendix-H identity holds with equality) — which is what the rust
# pipelined-vs-fused parity tests assert.
TINY = MoEConfig("tiny", L=2, B=2, N=16, M=32, H=64, E=4, k=2, f=4.0, n_heads=4, vocab=128)

# E2E: the ~100M-parameter end-to-end training config (examples/train_e2e.rs).
# params ~= 6 * (4*512^2 + 512*8) + 6 * 8*2*512*2048 + 4096*512
#        ~= 6.3M (MHA+gate) + 100.7M (experts) + 2.1M (embed) ~= 109M.
E2E = MoEConfig("e2e", L=6, B=4, N=128, M=512, H=2048, E=8, k=1, n_heads=8, vocab=4096)

PRESETS = {
    c.name: c
    for c in [
        GPT2_TINY_MOE,
        BERT_LARGE_MOE,
        LLAMA2_MOE,
        LLAMA2_MOE_L,
        DEEPSEEK_V2_S,
        DEEPSEEK_V2_M,
        TINY,
        E2E,
    ]
}
