"""Pallas expert-FFN kernel (L1) — the MoE compute hot spot.

The paper's E_r^(l) task: for every local expert e, compute
``relu(x[e] @ w1[e]) @ w2[e]`` over the (C, M) token slab routed to it.

TPU adaptation of the paper's CUDA formulation (DESIGN.md §2): what the GPU
frameworks express as one CUDA stream per expert with shared-memory tiles
becomes the Pallas *grid* — one grid step per (expert, token-tile) — with
BlockSpecs staging an ``(Ct, M)`` token tile plus both weight matrices
through VMEM. The intermediate ``(Ct, H)`` activation never round-trips to
HBM: both matmuls and the relu fuse inside a single kernel invocation, each
matmul mapping onto the 128x128 MXU.

VMEM budget per grid step (f32): Ct*M + M*H + H*M + Ct*H + Ct*M floats.
``_pick_token_tile`` chooses Ct so this stays under ~12 MiB of the 16 MiB
VMEM, double-buffering headroom included. Must run with interpret=True on
CPU (Mosaic custom-calls cannot execute on the CPU PJRT plugin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Leave headroom below the 16 MiB VMEM for double buffering + spills.
_VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _pick_token_tile(C: int, M: int, H: int, bytes_per_el: int = 4) -> int:
    """Largest power-of-two token tile Ct <= C whose working set fits VMEM."""
    weights = (M * H + H * M) * bytes_per_el
    ct = 1
    best = 1
    while ct <= C:
        work = weights + (2 * ct * M + ct * H) * bytes_per_el
        if work <= _VMEM_BUDGET_BYTES:
            best = ct
        ct *= 2
    return best


def _ffn_kernel(x_ref, w1_ref, w2_ref, o_ref):
    """One grid step: (Ct, M) @ (M, H) -> relu -> @ (H, M)."""
    h = jnp.dot(x_ref[0], w1_ref[0], preferred_element_type=jnp.float32)
    h = jnp.maximum(h, 0.0)
    o_ref[0] = jnp.dot(h, w2_ref[0], preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("token_tile",))
def expert_ffn(x, w1, w2, token_tile: int | None = None):
    """Batched expert FFN via a Pallas kernel.

    Args:
        x:  (E, C, M) tokens routed to each expert.
        w1: (E, M, H) first feed-forward weights.
        w2: (E, H, M) second feed-forward weights.
        token_tile: override the token tile Ct (must divide C); None =
            auto-pick for the VMEM budget.
    Returns:
        (E, C, M) expert outputs; matches ``ref.expert_ffn_ref`` exactly.
    """
    E, C, M = x.shape
    H = w1.shape[2]
    ct = token_tile or _pick_token_tile(C, M, H)
    if C % ct != 0:
        ct = 1  # fallback: always divides
    grid = (E, C // ct)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ct, M), lambda e, t: (e, t, 0)),
            pl.BlockSpec((1, M, H), lambda e, t: (e, 0, 0)),
            pl.BlockSpec((1, H, M), lambda e, t: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ct, M), lambda e, t: (e, t, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, M), x.dtype),
        interpret=True,
    )(x, w1, w2)


def vmem_report(C: int, M: int, H: int) -> dict:
    """Static VMEM/MXU analysis for a config (used by DESIGN/EXPERIMENTS).

    Returns the chosen tile, VMEM working-set bytes, and an MXU-utilization
    estimate: fraction of matmul dims that are multiples of the 128-wide
    systolic array (padding waste model).
    """
    ct = _pick_token_tile(C, M, H)
    vmem = (M * H + H * M + 2 * ct * M + ct * H) * 4

    def eff(d):
        pad = (128 - d % 128) % 128
        return d / (d + pad)

    # Two matmuls: (ct,M)x(M,H) and (ct,H)x(H,M).
    mxu = (eff(ct) * eff(M) * eff(H) + eff(ct) * eff(H) * eff(M)) / 2.0
    return {"token_tile": ct, "vmem_bytes": vmem, "mxu_utilization_est": mxu}
