"""Pallas top-k gating kernel (L1).

The paper's gating function G: score each token against the expert
embedding matrix, softmax, and pick the top-k experts. On GPU the reference
frameworks use a radix/sort-based top-k; on TPU we use the branch-free
iterative-argmax formulation — k passes of (max, one-hot mask-out) on the
VPU — which avoids any sort network and keeps everything dense and
vectorizable. The score matmul (T, M) x (M, E) targets the MXU.

Runs under interpret=True (CPU PJRT cannot execute Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _gating_kernel(x_ref, wg_ref, probs_ref, idx_ref, gate_ref, *, k):
    logits = jnp.dot(x_ref[...], wg_ref[...], preferred_element_type=jnp.float32)
    # numerically stable softmax
    m = jnp.max(logits, axis=-1, keepdims=True)
    ex = jnp.exp(logits - m)
    probs = ex / jnp.sum(ex, axis=-1, keepdims=True)
    probs_ref[...] = probs.astype(probs_ref.dtype)

    # iterative argmax top-k (branch-free, VPU-friendly)
    work = probs
    E = probs.shape[-1]
    eidx = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
    total = jnp.zeros(probs.shape[:-1] + (1,), jnp.float32)
    picked_g = []
    picked_i = []
    for j in range(k):
        best = jnp.max(work, axis=-1, keepdims=True)
        is_best = work == best
        # break ties toward the smallest expert index
        first = jnp.min(jnp.where(is_best, eidx, E), axis=-1, keepdims=True)
        onehot = eidx == first
        picked_g.append(best[..., 0])
        picked_i.append(first[..., 0].astype(jnp.int32))
        total = total + best
        work = jnp.where(onehot, _NEG, work)
    gate = jnp.stack(picked_g, axis=-1)
    gate = gate / jnp.maximum(total, 1e-9)
    idx_ref[...] = jnp.stack(picked_i, axis=-1)
    gate_ref[...] = gate.astype(gate_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "token_tile"))
def gating_topk(x, wg, k: int, token_tile: int | None = None):
    """Top-k softmax gating via a Pallas kernel.

    Args:
        x:  (T, M) tokens.
        wg: (M, E) gate projection.
        k:  experts per token.
        token_tile: tokens per grid step (None = all T in one step).
    Returns:
        (probs, topk_idx, topk_gate) matching ``ref.gating_ref`` (ties broken
        toward the smaller expert index, as jax.lax.top_k does).
    """
    T, M = x.shape
    E = wg.shape[1]
    tt = token_tile or T
    if T % tt != 0:
        tt = T
    grid = (T // tt,)
    kern = functools.partial(_gating_kernel, k=k)
    probs, idx, gate = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tt, M), lambda t: (t, 0)),
            pl.BlockSpec((M, E), lambda t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tt, E), lambda t: (t, 0)),
            pl.BlockSpec((tt, k), lambda t: (t, 0)),
            pl.BlockSpec((tt, k), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, E), x.dtype),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, k), x.dtype),
        ],
        interpret=True,
    )(x, wg)
    return probs, idx, gate
