"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package has an exact reference here, written with
nothing but ``jax.numpy`` ops so the semantics are unambiguous. The pytest
suite asserts ``assert_allclose(kernel(...), ref(...))`` over hypothesis-
generated shapes; these oracles are also what the L2 model uses when
``use_pallas=False``.
"""

import jax
import jax.numpy as jnp


def expert_ffn_ref(x, w1, w2):
    """Batched expert FFN: per expert e, relu(x[e] @ w1[e]) @ w2[e].

    Args:
        x:  (E, C, M) tokens routed to each expert.
        w1: (E, M, H) first feed-forward weights.
        w2: (E, H, M) second feed-forward weights.
    Returns:
        (E, C, M) expert outputs.
    """
    h = jnp.einsum("ecm,emh->ech", x, w1)
    h = jax.nn.relu(h)
    return jnp.einsum("ech,ehm->ecm", h, w2)


def topk_ref(probs, k):
    """Iterative-argmax top-k (ties to the smaller index, matching
    ``jax.lax.top_k``). Used instead of ``lax.top_k`` because the latter
    lowers to a ``topk(..., largest=true)`` HLO attribute that the rust
    loader's xla_extension 0.5.1 text parser rejects; this decomposition
    emits only plain reduce/select ops and is differentiable (gradients
    scatter to the selected entries, like top_k's)."""
    E = probs.shape[-1]
    eidx = jax.lax.broadcasted_iota(jnp.int32, probs.shape, probs.ndim - 1)
    work = probs
    vals, idxs = [], []
    for _ in range(k):
        best = jnp.max(work, axis=-1, keepdims=True)
        is_best = work == best
        first = jnp.min(jnp.where(is_best, eidx, E), axis=-1, keepdims=True)
        onehot = eidx == first
        # differentiable gather of the selected value
        vals.append(jnp.sum(jnp.where(onehot, probs, 0.0), axis=-1))
        idxs.append(first[..., 0].astype(jnp.int32))
        work = jnp.where(onehot, -jnp.inf, work)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def gating_ref(x, wg, k):
    """Top-k softmax gating (GShard-style, normalized over selected experts).

    Args:
        x:  (T, M) tokens.
        wg: (M, E) gate projection.
        k:  number of experts per token.
    Returns:
        (probs, topk_idx, topk_gate):
        probs:     (T, E) full softmax probabilities.
        topk_idx:  (T, k) int32 selected expert ids, by descending prob.
        topk_gate: (T, k) gate weights renormalized over the top-k.
    """
    logits = x @ wg
    probs = jax.nn.softmax(logits, axis=-1)
    topk_gate, topk_idx = topk_ref(probs, k)
    denom = jnp.sum(topk_gate, axis=-1, keepdims=True)
    topk_gate = topk_gate / jnp.maximum(denom, 1e-9)
    return probs, topk_idx.astype(jnp.int32), topk_gate


def attention_ref(q, k, v):
    """Scaled dot-product attention over (B, NH, N, D) tensors, no mask."""
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(d))
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


def attention_causal_ref(q, k, v):
    """Causal scaled dot-product attention over (B, NH, N, D)."""
    d = q.shape[-1]
    n = q.shape[-2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(d))
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


def dispatch_ref(x, topk_idx, topk_gate, E, C):
    """Build the (E, C, M) dispatch tensor + combine metadata from routing.

    Tokens beyond an expert's capacity C are dropped (GShard semantics).

    Args:
        x:         (T, M) tokens.
        topk_idx:  (T, k) selected expert per token per slot.
        topk_gate: (T, k) gate weights.
    Returns:
        (dispatched, comb):
        dispatched: (E, C, M) routed tokens (zero-padded).
        comb:       (T, k, 2) int32 [expert, slot] per token-choice; slot == C
                    marks a dropped token.
    """
    T, k = topk_idx.shape
    # position of each (token, choice) within its expert's queue
    onehot = jax.nn.one_hot(topk_idx, E, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(T * k, E)
    pos_within = jnp.cumsum(flat, axis=0) - flat  # (T*k, E)
    slot = jnp.sum(pos_within * flat, axis=-1).reshape(T, k)  # (T, k)
    expert = topk_idx
    valid = slot < C
    slot_c = jnp.where(valid, slot, C)  # C = drop bucket

    disp = jnp.zeros((E, C + 1, x.shape[1]), x.dtype)
    tok = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k))
    disp = disp.at[expert.reshape(-1), slot_c.reshape(-1)].add(x[tok.reshape(-1)])
    comb = jnp.stack([expert, slot_c], axis=-1).astype(jnp.int32)
    return disp[:, :C, :], comb


def combine_ref(expert_out, comb, topk_gate, T):
    """Inverse of dispatch: weighted gather of expert outputs per token.

    Args:
        expert_out: (E, C, M).
        comb:       (T, k, 2) [expert, slot] with slot == C meaning dropped.
        topk_gate:  (T, k).
    Returns:
        (T, M) combined outputs.
    """
    E, C, M = expert_out.shape
    padded = jnp.concatenate([expert_out, jnp.zeros((E, 1, M), expert_out.dtype)], axis=1)
    e = comb[..., 0]
    s = comb[..., 1]
    gathered = padded[e, s]  # (T, k, M)
    return jnp.einsum("tkm,tk->tm", gathered, topk_gate)


def moe_layer_ref(x, wg, w1, w2, k, C):
    """Full single-worker MoE layer: gate -> dispatch -> experts -> combine.

    Args:
        x: (T, M), wg: (M, E), w1: (E, M, H), w2: (E, H, M).
    Returns:
        (T, M) layer output.
    """
    E = wg.shape[1]
    _, topk_idx, topk_gate = gating_ref(x, wg, k)
    disp, comb = dispatch_ref(x, topk_idx, topk_gate, E, C)
    out = expert_ffn_ref(disp, w1, w2)
    return combine_ref(out, comb, topk_gate, x.shape[0])


def rmsnorm_ref(x, g, eps=1e-6):
    """RMSNorm over the last axis with learnable gain g."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g
