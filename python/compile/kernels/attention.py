"""Pallas fused attention kernel (L1) — the MHA hot spot (paper's AT task).

Flash-attention-style streaming formulation adapted for TPU: the grid walks
(batch*head, q-block); for each q-block the kernel streams over kv-blocks
with an online-softmax accumulator, so the N x N score matrix only ever
exists as a (Bq, Bk) tile in VMEM — the TPU analogue of the CUDA
shared-memory tiling the GPU implementations use.

Runs under interpret=True (CPU PJRT cannot execute Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bk, causal, q_block, scale):
    q = q_ref[0].astype(jnp.float32) * scale  # (Bq, D)
    n = k_ref.shape[1]
    bq = q.shape[0]
    qi = pl.program_id(1)

    acc = jnp.zeros((bq, v_ref.shape[2]), jnp.float32)
    m_i = jnp.full((bq, 1), _NEG, jnp.float32)
    l_i = jnp.zeros((bq, 1), jnp.float32)

    def body(s, carry):
        acc, m_i, l_i = carry
        kblk = jax.lax.dynamic_slice_in_dim(k_ref[0], s * bk, bk, axis=0)
        vblk = jax.lax.dynamic_slice_in_dim(v_ref[0], s * bk, bk, axis=0)
        scores = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32)  # (Bq, Bk)
        if causal:
            qpos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = s * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            scores = jnp.where(kpos <= qpos, scores, _NEG)
        m_new = jnp.maximum(m_i, jnp.max(scores, axis=-1, keepdims=True))
        p = jnp.exp(scores - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, vblk.astype(jnp.float32))
        return acc, m_new, l_new

    acc, m_i, l_i = jax.lax.fori_loop(0, n // bk, body, (acc, m_i, l_i))
    o_ref[0] = (acc / jnp.maximum(l_i, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "kv_block"))
def attention(q, k, v, causal: bool = False, q_block: int | None = None, kv_block: int | None = None):
    """Fused scaled-dot-product attention via a Pallas kernel.

    Args:
        q, k, v: (B, NH, N, D).
        causal:  apply a causal mask.
        q_block / kv_block: tile sizes (must divide N); None = auto.
    Returns:
        (B, NH, N, D) attention outputs, matching ``ref.attention_ref`` /
        ``ref.attention_causal_ref``.
    """
    B, NH, N, D = q.shape
    bq = q_block or min(N, 128)
    bk = kv_block or min(N, 128)
    if N % bq != 0:
        bq = N
    if N % bk != 0:
        bk = N
    scale = 1.0 / (D ** 0.5)

    qf = q.reshape(B * NH, N, D)
    kf = k.reshape(B * NH, N, D)
    vf = v.reshape(B * NH, N, D)
    kern = functools.partial(_attn_kernel, bk=bk, causal=causal, q_block=bq, scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(B * NH, N // bq),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, N, D), lambda h, t: (h, 0, 0)),
            pl.BlockSpec((1, N, D), lambda h, t: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, t: (h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B * NH, N, D), q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(B, NH, N, D)
