import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

import jax

jax.config.update("jax_platform_name", "cpu")
